//! Weight quantization: RTN, GPTQ, and the mixed-precision baselines
//! (QUIK-like, Atom-like) of Appendix E. Activation/KV quantization is
//! fake-quant inside the forward graphs (`model::forward`, `fwdq_*`
//! artifacts); this module quantizes *weights* host-side and returns
//! dequantized f32 weights ready for the artifacts.

mod gptq;
mod omniquant;

pub use gptq::{gptq_quantize_layer, gptq_quantize_model, GptqConfig};
pub use omniquant::{omniquant_quantize_mat, omniquant_quantize_model};

use crate::model::Weights;
use crate::tensor::Mat;

/// Per-output-channel symmetric RTN fake quantization of a weight matrix
/// ([out, in]; one scale per output row) — the paper's weight quantizer.
pub fn rtn_quantize_mat(w: &Mat, bits: u8) -> Mat {
    if bits >= 16 {
        return w.clone();
    }
    let qmax = ((1i32 << (bits - 1)) - 1) as f32;
    let mut out = w.clone();
    for i in 0..out.rows {
        let row = out.row_mut(i);
        let amax = row.iter().map(|v| v.abs()).fold(0.0f32, f32::max);
        let scale = (amax / qmax).max(1e-10);
        for v in row.iter_mut() {
            *v = (*v / scale).round().clamp(-qmax - 1.0, qmax) * scale;
        }
    }
    out
}

/// Quantize all transformer linears (embed/head stay fp, as in the paper).
pub fn rtn_quantize_model(weights: &Weights, bits: u8) -> Weights {
    let mut out = weights.clone();
    out.map_linear_weights(|_, m| {
        *m = rtn_quantize_mat(m, bits);
    });
    out
}

/// Mean squared error of RTN at a given width (weight-quant metric).
pub fn rtn_mse(w: &Mat, bits: u8) -> f64 {
    let q = rtn_quantize_mat(w, bits);
    let n = w.data.len() as f64;
    w.data
        .iter()
        .zip(&q.data)
        .map(|(a, b)| ((a - b) as f64).powi(2))
        .sum::<f64>()
        / n
}

/// QUIK-like mixed precision: protect the `keep` highest-magnitude input
/// channels (by calibration abs-max) in fp16, quantize the rest to `bits`.
/// The paper's comparison protects 256 channels on 4096-dim models; we
/// scale that ratio (1/16 of channels).
pub fn quik_quantize_mat(w: &Mat, act_absmax: &[f32], keep: usize, bits: u8) -> Mat {
    assert_eq!(act_absmax.len(), w.cols);
    let mut idx: Vec<usize> = (0..w.cols).collect();
    idx.sort_by(|&a, &b| act_absmax[b].partial_cmp(&act_absmax[a]).unwrap());
    let protected: std::collections::HashSet<usize> = idx.into_iter().take(keep).collect();
    let qmax = ((1i32 << (bits - 1)) - 1) as f32;
    let mut out = w.clone();
    for i in 0..out.rows {
        // Scale from the unprotected columns only.
        let amax = (0..w.cols)
            .filter(|c| !protected.contains(c))
            .map(|c| w.at(i, c).abs())
            .fold(0.0f32, f32::max);
        let scale = (amax / qmax).max(1e-10);
        for c in 0..w.cols {
            if !protected.contains(&c) {
                let v = out.at(i, c);
                *out.at_mut(i, c) = (v / scale).round().clamp(-qmax - 1.0, qmax) * scale;
            }
        }
    }
    out
}

/// Atom-like mixed precision: reorder channels by activation magnitude and
/// quantize in groups with per-group scales (group size 32), keeping the
/// top group in 8 bits. Captures Atom's grouped + reordered scheme at our
/// scale.
pub fn atom_quantize_mat(w: &Mat, act_absmax: &[f32], bits: u8) -> Mat {
    assert_eq!(act_absmax.len(), w.cols);
    let mut order: Vec<usize> = (0..w.cols).collect();
    order.sort_by(|&a, &b| act_absmax[b].partial_cmp(&act_absmax[a]).unwrap());
    const GROUP: usize = 32;
    let qmax_lo = ((1i32 << (bits - 1)) - 1) as f32;
    let qmax_hi = ((1i32 << 7) - 1) as f32; // top group in 8-bit
    let mut out = w.clone();
    for i in 0..out.rows {
        for (g, chunk) in order.chunks(GROUP).enumerate() {
            let qmax = if g == 0 { qmax_hi } else { qmax_lo };
            let amax = chunk.iter().map(|&c| w.at(i, c).abs()).fold(0.0f32, f32::max);
            let scale = (amax / qmax).max(1e-10);
            for &c in chunk {
                let v = out.at(i, c);
                *out.at_mut(i, c) = (v / scale).round().clamp(-qmax - 1.0, qmax) * scale;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg64;
    use crate::util::propcheck::{gen, Runner};

    fn rand_mat(seed: u64, r: usize, c: usize) -> Mat {
        let mut rng = Pcg64::new(seed);
        Mat::from_fn(r, c, |_, _| rng.normal())
    }

    #[test]
    fn rtn_error_bounded_by_half_step() {
        let w = rand_mat(1, 16, 64);
        let q = rtn_quantize_mat(&w, 4);
        for i in 0..w.rows {
            let amax = w.row(i).iter().map(|v| v.abs()).fold(0.0f32, f32::max);
            let step = amax / 7.0;
            for (a, b) in w.row(i).iter().zip(q.row(i)) {
                assert!((a - b).abs() <= step / 2.0 + 1e-5);
            }
        }
    }

    #[test]
    fn rtn_16_bits_is_identity_and_more_bits_less_error() {
        let w = rand_mat(2, 8, 32);
        assert_eq!(rtn_quantize_mat(&w, 16), w);
        assert!(rtn_mse(&w, 8) < rtn_mse(&w, 4));
        assert!(rtn_mse(&w, 4) < rtn_mse(&w, 2));
    }

    #[test]
    fn rtn_level_count_respected() {
        let w = rand_mat(3, 4, 256);
        let q = rtn_quantize_mat(&w, 4);
        for i in 0..q.rows {
            let mut vals: Vec<i64> =
                q.row(i).iter().map(|v| (v * 1e4).round() as i64).collect();
            vals.sort_unstable();
            vals.dedup();
            assert!(vals.len() <= 16, "row {i} has {} levels", vals.len());
        }
    }

    #[test]
    fn rtn_model_keeps_embed_head_fp() {
        let cfg = crate::model::ModelConfig::builtin("llama2-tiny").unwrap();
        let w = Weights::default_synthetic(&cfg, 1);
        let q = rtn_quantize_model(&w, 4);
        assert_eq!(q.get("embed").data, w.get("embed").data);
        assert_eq!(q.get("head").data, w.get("head").data);
        assert_ne!(q.get("l0.wq").data, w.get("l0.wq").data);
    }

    #[test]
    fn quik_protects_top_channels_exactly() {
        let w = rand_mat(4, 8, 64);
        let mut absmax = vec![1.0f32; 64];
        absmax[5] = 100.0;
        absmax[17] = 50.0;
        let q = quik_quantize_mat(&w, &absmax, 2, 4);
        for i in 0..w.rows {
            assert_eq!(w.at(i, 5), q.at(i, 5));
            assert_eq!(w.at(i, 17), q.at(i, 17));
        }
        // and quik beats plain rtn when outlier weight columns align
        let mut w2 = w.clone();
        for i in 0..w2.rows {
            *w2.at_mut(i, 5) *= 30.0;
        }
        let mse_rtn = rtn_mse(&w2, 4);
        let qk = quik_quantize_mat(&w2, &absmax, 2, 4);
        let mse_quik = w2
            .data
            .iter()
            .zip(&qk.data)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            / w2.data.len() as f64;
        assert!(mse_quik < mse_rtn, "{mse_quik} vs {mse_rtn}");
    }

    #[test]
    fn atom_grouping_beats_plain_rtn_on_skewed_weights() {
        let mut rng = Pcg64::new(5);
        // Column magnitudes vary wildly (grouped scales should win).
        let w = Mat::from_fn(8, 128, |_, c| rng.normal() * (1.0 + (c % 13) as f32));
        let absmax: Vec<f32> = (0..128).map(|c| 1.0 + (c % 13) as f32).collect();
        let qa = atom_quantize_mat(&w, &absmax, 4);
        let mse_atom = w
            .data
            .iter()
            .zip(&qa.data)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            / w.data.len() as f64;
        assert!(mse_atom < rtn_mse(&w, 4));
    }

    #[test]
    fn prop_rtn_idempotent() {
        Runner::new().cases(24).run("rtn idempotent", |rng| {
            let r = gen::size(rng, 1, 8);
            let c = gen::size(rng, 4, 64);
            let w = Mat::from_vec(r, c, gen::vec_f32(rng, r * c));
            let q1 = rtn_quantize_mat(&w, 4);
            let q2 = rtn_quantize_mat(&q1, 4);
            let d = q1.max_abs_diff(&q2);
            if d < 1e-4 * q1.max_abs().max(1.0) {
                Ok(())
            } else {
                Err(format!("not idempotent: {d}"))
            }
        });
    }
}
