//! Weight quantization: RTN, GPTQ, and the mixed-precision baselines
//! (QUIK-like, Atom-like) of Appendix E. Activation/KV quantization is
//! fake-quant inside the forward graphs (`model::forward`, `fwdq_*`
//! artifacts); this module quantizes *weights* host-side.
//!
//! Every quantizer funnels through the one shared scale/round/clamp
//! kernel (`tensor::QuantSpec` + `tensor::quantize_into`) and can emit a
//! packed [`QMat`] (`*_quantize_qmat`, `*_quantize_model_packed`) holding
//! integer codes + scales — the representation whose `nbytes()` is the
//! real memory story. The historical `*_quantize_mat` functions survive
//! as dequantizing wrappers whose output is **bit-identical** to the
//! pre-refactor fake-quant loops (property-tested below); bit widths
//! outside the packed range (9..=15) take a small f32 fallback with the
//! same math.

mod gptq;
mod omniquant;

pub use gptq::{
    gptq_quantize_layer, gptq_quantize_layer_qmat, gptq_quantize_model,
    gptq_quantize_model_packed, gptq_quantize_store, GptqConfig,
};
pub use omniquant::{
    omniquant_quantize_mat, omniquant_quantize_model, omniquant_quantize_model_packed,
    omniquant_quantize_qmat,
};
// Crate-internal: the coordinator's sharded GPTQ/OmniQuant stages reuse
// the per-layer setup and the row-range decomposition units directly.
pub(crate) use gptq::{
    gptq_capture_hessians, gptq_prepare, gptq_propagate_rows, gptq_sites, gptq_snap_wide,
};
pub(crate) use omniquant::{clip_qmax, clipped_scales_range, omniquant_snap_wide};

use crate::model::Weights;
use crate::tensor::{Mat, QMat, QuantSpec};

/// Group size of the Atom-like grouped scheme (top group kept at 8 bits).
pub const ATOM_GROUP: usize = 32;

/// Round/clamp one value onto the symmetric grid `scale` at `qmax` — the
/// f32 form of the shared kernel, used by the wide-bit fallbacks and
/// GPTQ's in-loop error propagation.
pub(crate) fn snap(v: f32, scale: f32, qmax: f32) -> f32 {
    (v / scale).round().clamp(-qmax - 1.0, qmax) * scale
}

/// qmax for bit widths outside the packed range (replicates the
/// historical `(1 << (bits - 1)) - 1` expression exactly).
pub(crate) fn wide_qmax(bits: u8) -> f32 {
    ((1i32 << (bits - 1)) - 1) as f32
}

// ---------------------------------------------------------------------------
// RTN
// ---------------------------------------------------------------------------

/// Per-output-channel symmetric RTN into packed codes (bits ∈ [2, 8]).
/// The result is panel-packed for the tiled GEMM so the pack cost is
/// paid here, at quantization time, not on the first forward.
pub fn rtn_quantize_qmat(w: &Mat, bits: u8) -> QMat {
    let q = QMat::quantize_rtn(w, QuantSpec::new(bits));
    q.prepack();
    q
}

/// Per-output-channel symmetric RTN fake quantization of a weight matrix
/// ([out, in]; one scale per output row) — the paper's weight quantizer.
/// Dequantizing wrapper over [`rtn_quantize_qmat`].
pub fn rtn_quantize_mat(w: &Mat, bits: u8) -> Mat {
    if bits >= 16 {
        return w.clone();
    }
    if QuantSpec::supports(bits) {
        return rtn_quantize_qmat(w, bits).dequantize();
    }
    // Wide grids (9..=15 bits) don't pack; same math on f32.
    let qmax = wide_qmax(bits);
    let mut out = w.clone();
    for i in 0..out.rows {
        let row = out.row_mut(i);
        let amax = row.iter().map(|v| v.abs()).fold(0.0f32, f32::max);
        let scale = (amax / qmax).max(1e-10);
        for v in row.iter_mut() {
            *v = snap(*v, scale, qmax);
        }
    }
    out
}

/// Quantize all transformer linears (embed/head stay fp, as in the paper).
pub fn rtn_quantize_model(weights: &Weights, bits: u8) -> Weights {
    let mut out = weights.clone();
    out.map_linear_weights(|_, m| {
        *m = rtn_quantize_mat(m, bits);
    });
    out
}

/// [`rtn_quantize_model`] with packed storage: every transformer linear
/// becomes a [`QMat`]. Falls back to the dense fake-quant model when
/// `bits` doesn't pack.
pub fn rtn_quantize_model_packed(weights: &Weights, bits: u8) -> Weights {
    if !QuantSpec::supports(bits) {
        return rtn_quantize_model(weights, bits);
    }
    let mut out = weights.clone();
    out.pack_linear_weights(|_, m| rtn_quantize_qmat(m, bits));
    out
}

/// [`rtn_quantize_model`] over a `model::WeightStore` (the streamed
/// pipeline's quantize stage): one layer checked out at a time,
/// quantized with the same per-matrix kernels, written back — packed
/// codes + scales when `packed` and the width packs, the dense
/// fake-quant otherwise. Output is **bit-identical** to the in-memory
/// pass; peak weight residency is one layer. See `docs/STREAMING.md`.
pub fn rtn_quantize_store(
    store: &crate::model::WeightStore,
    bits: u8,
    packed: bool,
) -> anyhow::Result<()> {
    let packed = packed && QuantSpec::supports(bits);
    for l in 0..store.cfg().n_layers {
        let mut lease = store.checkout_layer(l)?;
        let names = lease.weights().names().to_vec();
        let w = lease.weights_mut();
        for name in &names {
            if packed {
                let q = rtn_quantize_qmat(w.get(name), bits);
                w.set_packed(name, q);
            } else {
                let q = rtn_quantize_mat(w.get(name), bits);
                w.set(name, q);
            }
        }
        lease.commit()?;
    }
    Ok(())
}

/// Mean squared error of RTN at a given width (weight-quant metric).
pub fn rtn_mse(w: &Mat, bits: u8) -> f64 {
    let q = rtn_quantize_mat(w, bits);
    let n = w.data.len() as f64;
    w.data
        .iter()
        .zip(&q.data)
        .map(|(a, b)| ((a - b) as f64).powi(2))
        .sum::<f64>()
        / n
}

// ---------------------------------------------------------------------------
// QUIK-like mixed precision
// ---------------------------------------------------------------------------

/// Protected-column mask: the `keep` highest-|activation| channels.
/// A `Vec<bool>` so the scale scan and quantize loops test membership in
/// O(1) instead of the historical per-element `HashSet::contains`.
/// `total_cmp` keeps the sort NaN-safe: a NaN/∞ calibration column (a
/// blown-up activation scan) sorts to the top and gets protected instead
/// of panicking the whole pipeline.
fn quik_mask(act_absmax: &[f32], keep: usize) -> Vec<bool> {
    let mut idx: Vec<usize> = (0..act_absmax.len()).collect();
    idx.sort_by(|&a, &b| act_absmax[b].total_cmp(&act_absmax[a]));
    let mut mask = vec![false; act_absmax.len()];
    for &c in idx.iter().take(keep) {
        mask[c] = true;
    }
    mask
}

/// QUIK-like mixed precision into packed codes: the protected channels
/// keep full precision in the QMat metadata, the rest quantize to `bits`.
pub fn quik_quantize_qmat(w: &Mat, act_absmax: &[f32], keep: usize, bits: u8) -> QMat {
    assert_eq!(act_absmax.len(), w.cols);
    let q = QMat::quantize_protected(w, QuantSpec::new(bits), &quik_mask(act_absmax, keep));
    q.prepack();
    q
}

/// QUIK-like mixed precision: protect the `keep` highest-magnitude input
/// channels (by calibration abs-max) in fp16, quantize the rest to `bits`.
/// The paper's comparison protects 256 channels on 4096-dim models; we
/// scale that ratio (1/16 of channels). Dequantizing wrapper over
/// [`quik_quantize_qmat`].
pub fn quik_quantize_mat(w: &Mat, act_absmax: &[f32], keep: usize, bits: u8) -> Mat {
    assert_eq!(act_absmax.len(), w.cols);
    if QuantSpec::supports(bits) {
        return quik_quantize_qmat(w, act_absmax, keep, bits).dequantize();
    }
    let mask = quik_mask(act_absmax, keep);
    let qmax = wide_qmax(bits);
    let mut out = w.clone();
    for i in 0..out.rows {
        // Scale from the unprotected columns only.
        let mut amax = 0.0f32;
        for c in 0..w.cols {
            if !mask[c] {
                amax = amax.max(w.at(i, c).abs());
            }
        }
        let scale = (amax / qmax).max(1e-10);
        for c in 0..w.cols {
            if !mask[c] {
                let v = out.at(i, c);
                *out.at_mut(i, c) = snap(v, scale, qmax);
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Atom-like mixed precision
// ---------------------------------------------------------------------------

/// Channel order by descending activation magnitude (`total_cmp`:
/// NaN/∞ columns deterministically lead the order instead of panicking).
fn atom_order(act_absmax: &[f32]) -> Vec<usize> {
    let mut order: Vec<usize> = (0..act_absmax.len()).collect();
    order.sort_by(|&a, &b| act_absmax[b].total_cmp(&act_absmax[a]));
    order
}

/// Atom-like mixed precision into packed codes: reordered per-group
/// scales (group size [`ATOM_GROUP`]), top group at 8 bits.
pub fn atom_quantize_qmat(w: &Mat, act_absmax: &[f32], bits: u8) -> QMat {
    assert_eq!(act_absmax.len(), w.cols);
    QMat::quantize_grouped(w, QuantSpec::new(bits), &atom_order(act_absmax), ATOM_GROUP)
}

/// Atom-like mixed precision: reorder channels by activation magnitude and
/// quantize in groups with per-group scales (group size 32), keeping the
/// top group in 8 bits. Captures Atom's grouped + reordered scheme at our
/// scale. Dequantizing wrapper over [`atom_quantize_qmat`].
pub fn atom_quantize_mat(w: &Mat, act_absmax: &[f32], bits: u8) -> Mat {
    assert_eq!(act_absmax.len(), w.cols);
    if QuantSpec::supports(bits) {
        return atom_quantize_qmat(w, act_absmax, bits).dequantize();
    }
    let order = atom_order(act_absmax);
    let qmax_lo = wide_qmax(bits);
    let qmax_hi = wide_qmax(8); // top group in 8-bit
    let mut out = w.clone();
    for i in 0..out.rows {
        for (g, chunk) in order.chunks(ATOM_GROUP).enumerate() {
            let qmax = if g == 0 { qmax_hi } else { qmax_lo };
            let amax = chunk.iter().map(|&c| w.at(i, c).abs()).fold(0.0f32, f32::max);
            let scale = (amax / qmax).max(1e-10);
            for &c in chunk {
                let v = out.at(i, c);
                *out.at_mut(i, c) = snap(v, scale, qmax);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg64;
    use crate::util::propcheck::{gen, Runner};

    fn rand_mat(seed: u64, r: usize, c: usize) -> Mat {
        let mut rng = Pcg64::new(seed);
        Mat::from_fn(r, c, |_, _| rng.normal())
    }

    /// Verbatim copies of the fake-quant loops this module replaced — the
    /// oracles for the bit-identity property tests below.
    mod pre_refactor {
        use crate::tensor::Mat;

        pub fn rtn(w: &Mat, bits: u8) -> Mat {
            let qmax = ((1i32 << (bits - 1)) - 1) as f32;
            let mut out = w.clone();
            for i in 0..out.rows {
                let row = out.row_mut(i);
                let amax = row.iter().map(|v| v.abs()).fold(0.0f32, f32::max);
                let scale = (amax / qmax).max(1e-10);
                for v in row.iter_mut() {
                    *v = (*v / scale).round().clamp(-qmax - 1.0, qmax) * scale;
                }
            }
            out
        }

        pub fn quik(w: &Mat, act_absmax: &[f32], keep: usize, bits: u8) -> Mat {
            let mut idx: Vec<usize> = (0..w.cols).collect();
            idx.sort_by(|&a, &b| act_absmax[b].total_cmp(&act_absmax[a]));
            let protected: std::collections::HashSet<usize> = idx.into_iter().take(keep).collect();
            let qmax = ((1i32 << (bits - 1)) - 1) as f32;
            let mut out = w.clone();
            for i in 0..out.rows {
                let amax = (0..w.cols)
                    .filter(|c| !protected.contains(c))
                    .map(|c| w.at(i, c).abs())
                    .fold(0.0f32, f32::max);
                let scale = (amax / qmax).max(1e-10);
                for c in 0..w.cols {
                    if !protected.contains(&c) {
                        let v = out.at(i, c);
                        *out.at_mut(i, c) = (v / scale).round().clamp(-qmax - 1.0, qmax) * scale;
                    }
                }
            }
            out
        }

        pub fn atom(w: &Mat, act_absmax: &[f32], bits: u8) -> Mat {
            let mut order: Vec<usize> = (0..w.cols).collect();
            order.sort_by(|&a, &b| act_absmax[b].total_cmp(&act_absmax[a]));
            const GROUP: usize = 32;
            let qmax_lo = ((1i32 << (bits - 1)) - 1) as f32;
            let qmax_hi = ((1i32 << 7) - 1) as f32;
            let mut out = w.clone();
            for i in 0..out.rows {
                for (g, chunk) in order.chunks(GROUP).enumerate() {
                    let qmax = if g == 0 { qmax_hi } else { qmax_lo };
                    let amax = chunk.iter().map(|&c| w.at(i, c).abs()).fold(0.0f32, f32::max);
                    let scale = (amax / qmax).max(1e-10);
                    for &c in chunk {
                        let v = out.at(i, c);
                        *out.at_mut(i, c) = (v / scale).round().clamp(-qmax - 1.0, qmax) * scale;
                    }
                }
            }
            out
        }
    }

    #[test]
    fn rtn_error_bounded_by_half_step() {
        let w = rand_mat(1, 16, 64);
        let q = rtn_quantize_mat(&w, 4);
        for i in 0..w.rows {
            let amax = w.row(i).iter().map(|v| v.abs()).fold(0.0f32, f32::max);
            let step = amax / 7.0;
            for (a, b) in w.row(i).iter().zip(q.row(i)) {
                assert!((a - b).abs() <= step / 2.0 + 1e-5);
            }
        }
    }

    #[test]
    fn rtn_16_bits_is_identity_and_more_bits_less_error() {
        let w = rand_mat(2, 8, 32);
        assert_eq!(rtn_quantize_mat(&w, 16), w);
        assert!(rtn_mse(&w, 8) < rtn_mse(&w, 4));
        assert!(rtn_mse(&w, 4) < rtn_mse(&w, 2));
    }

    #[test]
    fn rtn_level_count_respected() {
        let w = rand_mat(3, 4, 256);
        let q = rtn_quantize_mat(&w, 4);
        for i in 0..q.rows {
            let mut vals: Vec<i64> =
                q.row(i).iter().map(|v| (v * 1e4).round() as i64).collect();
            vals.sort_unstable();
            vals.dedup();
            assert!(vals.len() <= 16, "row {i} has {} levels", vals.len());
        }
    }

    #[test]
    fn rtn_model_keeps_embed_head_fp() {
        let cfg = crate::model::ModelConfig::builtin("llama2-tiny").unwrap();
        let w = Weights::default_synthetic(&cfg, 1);
        let q = rtn_quantize_model(&w, 4);
        assert_eq!(q.get("embed").data, w.get("embed").data);
        assert_eq!(q.get("head").data, w.get("head").data);
        assert_ne!(q.get("l0.wq").data, w.get("l0.wq").data);
    }

    #[test]
    fn packed_model_matches_dense_model_bit_for_bit() {
        let cfg = crate::model::ModelConfig::builtin("llama2-tiny").unwrap();
        let w = Weights::default_synthetic(&cfg, 1);
        let dense = rtn_quantize_model(&w, 4);
        let packed = rtn_quantize_model_packed(&w, 4);
        assert!(packed.has_packed());
        assert!(packed.nbytes() < dense.nbytes());
        for n in w.names() {
            assert_eq!(packed.tensor(n).to_mat().data, dense.tensor(n).to_mat().data, "{n}");
        }
        // embed/head stay dense even in the packed model
        assert!(packed.tensor("embed").as_f32().is_some());
        assert!(packed.tensor("head").as_f32().is_some());
    }

    #[test]
    fn quik_protects_top_channels_exactly() {
        let w = rand_mat(4, 8, 64);
        let mut absmax = vec![1.0f32; 64];
        absmax[5] = 100.0;
        absmax[17] = 50.0;
        let q = quik_quantize_mat(&w, &absmax, 2, 4);
        for i in 0..w.rows {
            assert_eq!(w.at(i, 5), q.at(i, 5));
            assert_eq!(w.at(i, 17), q.at(i, 17));
        }
        // and quik beats plain rtn when outlier weight columns align
        let mut w2 = w.clone();
        for i in 0..w2.rows {
            *w2.at_mut(i, 5) *= 30.0;
        }
        let mse_rtn = rtn_mse(&w2, 4);
        let qk = quik_quantize_mat(&w2, &absmax, 2, 4);
        let mse_quik = w2
            .data
            .iter()
            .zip(&qk.data)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            / w2.data.len() as f64;
        assert!(mse_quik < mse_rtn, "{mse_quik} vs {mse_rtn}");
    }

    #[test]
    fn atom_grouping_beats_plain_rtn_on_skewed_weights() {
        let mut rng = Pcg64::new(5);
        // Column magnitudes vary wildly (grouped scales should win).
        let w = Mat::from_fn(8, 128, |_, c| rng.normal() * (1.0 + (c % 13) as f32));
        let absmax: Vec<f32> = (0..128).map(|c| 1.0 + (c % 13) as f32).collect();
        let qa = atom_quantize_mat(&w, &absmax, 4);
        let mse_atom = w
            .data
            .iter()
            .zip(&qa.data)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            / w.data.len() as f64;
        assert!(mse_atom < rtn_mse(&w, 4));
    }

    #[test]
    fn nan_or_inf_activation_columns_do_not_panic_the_sorts() {
        // Regression: the quik/atom channel sorts used
        // `partial_cmp(..).unwrap()`, which panicked on a NaN activation
        // scan. `total_cmp` sorts NaN/∞ to the top deterministically.
        let w = rand_mat(9, 6, 64);
        let mut absmax = vec![1.0f32; 64];
        absmax[3] = f32::NAN;
        absmax[5] = f32::INFINITY;
        let qk = quik_quantize_mat(&w, &absmax, 2, 4);
        // The NaN and ∞ columns rank highest → both protected verbatim.
        for i in 0..w.rows {
            assert_eq!(qk.at(i, 3), w.at(i, 3));
            assert_eq!(qk.at(i, 5), w.at(i, 5));
        }
        assert!(qk.data.iter().all(|v| v.is_finite()), "weights stay finite");
        let qa = atom_quantize_mat(&w, &absmax, 4);
        assert!(qa.data.iter().all(|v| v.is_finite()));
        // Deterministic: the same poisoned scan yields the same output.
        assert_eq!(qa.data, atom_quantize_mat(&w, &absmax, 4).data);
        // Packed constructors run the same sorts — no panic there either.
        let _ = quik_quantize_qmat(&w, &absmax, 2, 4);
        let _ = atom_quantize_qmat(&w, &absmax, 4);
    }

    #[test]
    fn prop_rtn_idempotent() {
        Runner::new().cases(24).run("rtn idempotent", |rng| {
            let r = gen::size(rng, 1, 8);
            let c = gen::size(rng, 4, 64);
            let w = Mat::from_vec(r, c, gen::vec_f32(rng, r * c));
            let q1 = rtn_quantize_mat(&w, 4);
            let q2 = rtn_quantize_mat(&q1, 4);
            let d = q1.max_abs_diff(&q2);
            if d < 1e-4 * q1.max_abs().max(1.0) {
                Ok(())
            } else {
                Err(format!("not idempotent: {d}"))
            }
        });
    }

    #[test]
    fn prop_rtn_qmat_bit_identical_to_pre_refactor() {
        Runner::new().cases(32).run("rtn QMat bit-identity", |rng| {
            let r = gen::size(rng, 1, 8);
            let c = gen::size(rng, 4, 80);
            let bits = [2u8, 3, 4, 5, 8][rng.below(5)];
            let w = Mat::from_vec(r, c, gen::vec_f32(rng, r * c));
            let q = rtn_quantize_qmat(&w, bits);
            if q.nbytes() >= q.dense_nbytes() {
                return Err(format!("no packing win at {bits} bits"));
            }
            if q.dequantize().data == pre_refactor::rtn(&w, bits).data {
                Ok(())
            } else {
                Err(format!("rtn mismatch at {bits} bits, shape {r}x{c}"))
            }
        });
    }

    #[test]
    fn prop_quik_qmat_bit_identical_to_pre_refactor() {
        Runner::new().cases(24).run("quik QMat bit-identity", |rng| {
            let r = gen::size(rng, 1, 6);
            let c = gen::size(rng, 8, 80);
            let bits = [2u8, 4, 8][rng.below(3)];
            let w = Mat::from_vec(r, c, gen::vec_f32(rng, r * c));
            let absmax = gen::activations(rng, c);
            let keep = gen::size(rng, 1, c / 2);
            let q = quik_quantize_qmat(&w, &absmax, keep, bits);
            if q.dequantize().data == pre_refactor::quik(&w, &absmax, keep, bits).data {
                Ok(())
            } else {
                Err(format!("quik mismatch at {bits} bits, keep {keep}, shape {r}x{c}"))
            }
        });
    }

    #[test]
    fn prop_atom_qmat_bit_identical_to_pre_refactor() {
        Runner::new().cases(24).run("atom QMat bit-identity", |rng| {
            let r = gen::size(rng, 1, 6);
            let c = gen::size(rng, 8, 96);
            let bits = [2u8, 4, 8][rng.below(3)];
            let w = Mat::from_vec(r, c, gen::vec_f32(rng, r * c));
            let absmax = gen::activations(rng, c);
            let q = atom_quantize_qmat(&w, &absmax, bits);
            if q.dequantize().data == pre_refactor::atom(&w, &absmax, bits).data {
                Ok(())
            } else {
                Err(format!("atom mismatch at {bits} bits, shape {r}x{c}"))
            }
        });
    }
}
