//! OmniQuant-like baseline: **learnable weight clipping** (the paper's
//! OmniQuant rows). The full method trains clipping ratios + equivalent
//! transforms block-wise with gradients; the essential mechanism — a
//! per-output-channel clip ratio γ ∈ (0, 1] chosen to minimize the
//! layer's weight-quantization MSE — is reproduced here with a direct
//! grid search (exact for the per-channel separable objective, no
//! gradients needed at our scale).

use crate::model::Weights;
use crate::tensor::Mat;

/// Candidate clip ratios searched per output channel.
const GRID: [f32; 12] =
    [0.35, 0.45, 0.55, 0.65, 0.7, 0.75, 0.8, 0.85, 0.9, 0.95, 0.98, 1.0];

/// Quantize one row with clip ratio γ: scale = γ·amax/qmax, values clamped
/// to the clipped grid.
fn quant_row(row: &[f32], gamma: f32, qmax: f32) -> Vec<f32> {
    let amax = row.iter().map(|v| v.abs()).fold(0.0f32, f32::max);
    let scale = (gamma * amax / qmax).max(1e-10);
    row.iter()
        .map(|&v| (v / scale).round().clamp(-qmax - 1.0, qmax) * scale)
        .collect()
}

/// Per-output-channel clipped RTN with MSE-optimal clip ratio.
pub fn omniquant_quantize_mat(w: &Mat, bits: u8) -> Mat {
    if bits >= 16 {
        return w.clone();
    }
    let qmax = ((1i32 << (bits - 1)) - 1) as f32;
    let mut out = w.clone();
    for i in 0..w.rows {
        let row = w.row(i);
        let mut best = (f64::MAX, GRID[GRID.len() - 1]);
        for &g in &GRID {
            let q = quant_row(row, g, qmax);
            let mse: f64 = row
                .iter()
                .zip(&q)
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum();
            if mse < best.0 {
                best = (mse, g);
            }
        }
        let q = quant_row(row, best.1, qmax);
        out.row_mut(i).copy_from_slice(&q);
    }
    out
}

/// Quantize all transformer linears with learnable clipping.
pub fn omniquant_quantize_model(weights: &Weights, bits: u8) -> Weights {
    let mut out = weights.clone();
    out.map_linear_weights(|_, m| {
        *m = omniquant_quantize_mat(m, bits);
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn_mse;
    use crate::util::prng::Pcg64;

    fn mse(a: &Mat, b: &Mat) -> f64 {
        a.data
            .iter()
            .zip(&b.data)
            .map(|(x, y)| ((x - y) as f64).powi(2))
            .sum::<f64>()
            / a.data.len() as f64
    }

    #[test]
    fn clipping_beats_plain_rtn_on_heavy_tails() {
        // Laplace rows: the rare tail values stretch the unclipped range,
        // and MSE-optimal clipping trades their error for finer steps on
        // the body (a lone huge outlier would NOT be clipped — its own
        // clip error dominates — which the grid search handles too).
        let mut rng = Pcg64::new(1);
        let w = Mat::from_fn(16, 256, |_, _| rng.laplace(2.0));
        let q = omniquant_quantize_mat(&w, 4);
        assert!(
            mse(&w, &q) < rtn_mse(&w, 4) * 0.8,
            "clipping should beat RTN: {} vs {}",
            mse(&w, &q),
            rtn_mse(&w, 4)
        );
    }

    #[test]
    fn never_worse_than_rtn() {
        // γ=1.0 is in the grid, so the optimum is ≤ plain RTN's MSE.
        let mut rng = Pcg64::new(2);
        for seed in 0..5 {
            let mut r2 = Pcg64::new(seed);
            let w = Mat::from_fn(8, 64, |_, _| r2.normal() * (1.0 + rng.uniform() as f32));
            let q = omniquant_quantize_mat(&w, 4);
            assert!(mse(&w, &q) <= rtn_mse(&w, 4) + 1e-12);
        }
    }

    #[test]
    fn sixteen_bit_identity_and_model_path() {
        let cfg = crate::model::ModelConfig::builtin("llama2-tiny").unwrap();
        let w = Weights::default_synthetic(&cfg, 1);
        assert_eq!(omniquant_quantize_mat(w.get("l0.wq"), 16), *w.get("l0.wq"));
        let q = omniquant_quantize_model(&w, 4);
        assert_eq!(q.get("embed").data, w.get("embed").data);
        assert_ne!(q.get("l0.wq").data, w.get("l0.wq").data);
    }

    #[test]
    fn output_respects_level_count() {
        let mut rng = Pcg64::new(3);
        let w = Mat::from_fn(4, 64, |_, _| rng.laplace(2.0));
        let q = omniquant_quantize_mat(&w, 4);
        for i in 0..q.rows {
            let mut vals: Vec<i64> = q.row(i).iter().map(|v| (v * 1e4).round() as i64).collect();
            vals.sort_unstable();
            vals.dedup();
            assert!(vals.len() <= 16);
        }
    }
}
