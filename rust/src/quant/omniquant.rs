//! OmniQuant-like baseline: **learnable weight clipping** (the paper's
//! OmniQuant rows). The full method trains clipping ratios + equivalent
//! transforms block-wise with gradients; the essential mechanism — a
//! per-output-channel clip ratio γ ∈ (0, 1] chosen to minimize the
//! layer's weight-quantization MSE — is reproduced here with a direct
//! grid search (exact for the per-channel separable objective, no
//! gradients needed at our scale). The chosen clipped scales feed the
//! shared QMat encode, so the packed output is bit-identical to the
//! historical fake-quant result.

use super::{snap, wide_qmax};
use crate::model::Weights;
use crate::tensor::{Mat, QMat, QuantSpec};

/// Candidate clip ratios searched per output channel.
const GRID: [f32; 12] =
    [0.35, 0.45, 0.55, 0.65, 0.7, 0.75, 0.8, 0.85, 0.9, 0.95, 0.98, 1.0];

/// Quantize one row with clip ratio γ: scale = γ·amax/qmax, values clamped
/// to the clipped grid.
fn quant_row(row: &[f32], gamma: f32, qmax: f32) -> Vec<f32> {
    let amax = row.iter().map(|v| v.abs()).fold(0.0f32, f32::max);
    let scale = (gamma * amax / qmax).max(1e-10);
    row.iter().map(|&v| snap(v, scale, qmax)).collect()
}

/// MSE-optimal per-row clipped scales (the grid search itself).
fn clipped_scales(w: &Mat, qmax: f32) -> Vec<f32> {
    clipped_scales_range(w, qmax, 0, w.rows)
}

/// [`clipped_scales`] restricted to rows `[lo, hi)`. The grid search is
/// per-row separable, so the coordinator decomposes one tensor's search
/// into `--shards` row-range sub-jobs and concatenates the results in
/// range order — bit-identical to the whole-matrix search.
pub(crate) fn clipped_scales_range(w: &Mat, qmax: f32, lo: usize, hi: usize) -> Vec<f32> {
    (lo..hi)
        .map(|i| {
            let row = w.row(i);
            let amax = row.iter().map(|v| v.abs()).fold(0.0f32, f32::max);
            let mut best = (f64::MAX, GRID[GRID.len() - 1]);
            for &g in &GRID {
                let q = quant_row(row, g, qmax);
                let mse: f64 = row
                    .iter()
                    .zip(&q)
                    .map(|(a, b)| ((a - b) as f64).powi(2))
                    .sum();
                if mse < best.0 {
                    best = (mse, g);
                }
            }
            (best.1 * amax / qmax).max(1e-10)
        })
        .collect()
}

/// The grid bound the clip search quantizes against for `bits` — the
/// same qmax [`omniquant_quantize_qmat`] (packing bits) and
/// [`omniquant_quantize_mat`] (wide bits) use internally, exposed so the
/// coordinator's sharded search calls [`clipped_scales_range`] with the
/// identical bound.
pub(crate) fn clip_qmax(bits: u8) -> f32 {
    if QuantSpec::supports(bits) {
        QuantSpec::new(bits).qmax()
    } else {
        wide_qmax(bits)
    }
}

/// The wide-grid tail of [`omniquant_quantize_mat`]: snap every row onto
/// the clipped f32 grid given precomputed scales.
pub(crate) fn omniquant_snap_wide(w: &Mat, scales: &[f32], bits: u8) -> Mat {
    let qmax = wide_qmax(bits);
    let mut out = w.clone();
    for i in 0..w.rows {
        let s = scales[i];
        for v in out.row_mut(i) {
            *v = snap(*v, s, qmax);
        }
    }
    out
}

/// Clipped RTN into packed codes (bits ∈ [2, 8]): the MSE-optimal
/// per-row scales feed the shared QMat encode.
pub fn omniquant_quantize_qmat(w: &Mat, bits: u8) -> QMat {
    let spec = QuantSpec::new(bits);
    let scales = clipped_scales(w, spec.qmax());
    let q = QMat::quantize_with_scales(w, spec, scales);
    q.prepack();
    q
}

/// Per-output-channel clipped RTN with MSE-optimal clip ratio.
/// Dequantizing wrapper over [`omniquant_quantize_qmat`].
pub fn omniquant_quantize_mat(w: &Mat, bits: u8) -> Mat {
    if bits >= 16 {
        return w.clone();
    }
    if QuantSpec::supports(bits) {
        return omniquant_quantize_qmat(w, bits).dequantize();
    }
    // Wide grids: snap onto the clipped f32 grid directly.
    let qmax = wide_qmax(bits);
    let scales = clipped_scales(w, qmax);
    let mut out = w.clone();
    for i in 0..w.rows {
        let s = scales[i];
        for v in out.row_mut(i) {
            *v = snap(*v, s, qmax);
        }
    }
    out
}

/// Quantize all transformer linears with learnable clipping.
pub fn omniquant_quantize_model(weights: &Weights, bits: u8) -> Weights {
    let mut out = weights.clone();
    out.map_linear_weights(|_, m| {
        *m = omniquant_quantize_mat(m, bits);
    });
    out
}

/// [`omniquant_quantize_model`] with packed storage. Falls back to the
/// dense fake-quant model when `bits` doesn't pack.
pub fn omniquant_quantize_model_packed(weights: &Weights, bits: u8) -> Weights {
    if !QuantSpec::supports(bits) {
        return omniquant_quantize_model(weights, bits);
    }
    let mut out = weights.clone();
    out.pack_linear_weights(|_, m| omniquant_quantize_qmat(m, bits));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn_mse;
    use crate::util::prng::Pcg64;

    fn mse(a: &Mat, b: &Mat) -> f64 {
        a.data
            .iter()
            .zip(&b.data)
            .map(|(x, y)| ((x - y) as f64).powi(2))
            .sum::<f64>()
            / a.data.len() as f64
    }

    /// Verbatim pre-refactor clipped RTN — the oracle for the QMat
    /// bit-identity property test.
    fn pre_refactor_omniquant(w: &Mat, bits: u8) -> Mat {
        let qmax = ((1i32 << (bits - 1)) - 1) as f32;
        let quant_row = |row: &[f32], gamma: f32| -> Vec<f32> {
            let amax = row.iter().map(|v| v.abs()).fold(0.0f32, f32::max);
            let scale = (gamma * amax / qmax).max(1e-10);
            row.iter()
                .map(|&v| (v / scale).round().clamp(-qmax - 1.0, qmax) * scale)
                .collect()
        };
        let mut out = w.clone();
        for i in 0..w.rows {
            let row = w.row(i);
            let mut best = (f64::MAX, GRID[GRID.len() - 1]);
            for &g in &GRID {
                let q = quant_row(row, g);
                let e: f64 = row.iter().zip(&q).map(|(a, b)| ((a - b) as f64).powi(2)).sum();
                if e < best.0 {
                    best = (e, g);
                }
            }
            out.row_mut(i).copy_from_slice(&quant_row(row, best.1));
        }
        out
    }

    #[test]
    fn clipping_beats_plain_rtn_on_heavy_tails() {
        // Laplace rows: the rare tail values stretch the unclipped range,
        // and MSE-optimal clipping trades their error for finer steps on
        // the body (a lone huge outlier would NOT be clipped — its own
        // clip error dominates — which the grid search handles too).
        let mut rng = Pcg64::new(1);
        let w = Mat::from_fn(16, 256, |_, _| rng.laplace(2.0));
        let q = omniquant_quantize_mat(&w, 4);
        assert!(
            mse(&w, &q) < rtn_mse(&w, 4) * 0.8,
            "clipping should beat RTN: {} vs {}",
            mse(&w, &q),
            rtn_mse(&w, 4)
        );
    }

    #[test]
    fn never_worse_than_rtn() {
        // γ=1.0 is in the grid, so the optimum is ≤ plain RTN's MSE.
        let mut rng = Pcg64::new(2);
        for seed in 0..5 {
            let mut r2 = Pcg64::new(seed);
            let w = Mat::from_fn(8, 64, |_, _| r2.normal() * (1.0 + rng.uniform() as f32));
            let q = omniquant_quantize_mat(&w, 4);
            assert!(mse(&w, &q) <= rtn_mse(&w, 4) + 1e-12);
        }
    }

    #[test]
    fn sixteen_bit_identity_and_model_path() {
        let cfg = crate::model::ModelConfig::builtin("llama2-tiny").unwrap();
        let w = Weights::default_synthetic(&cfg, 1);
        assert_eq!(omniquant_quantize_mat(w.get("l0.wq"), 16), *w.get("l0.wq"));
        let q = omniquant_quantize_model(&w, 4);
        assert_eq!(q.get("embed").data, w.get("embed").data);
        assert_ne!(q.get("l0.wq").data, w.get("l0.wq").data);
    }

    #[test]
    fn output_respects_level_count() {
        let mut rng = Pcg64::new(3);
        let w = Mat::from_fn(4, 64, |_, _| rng.laplace(2.0));
        let q = omniquant_quantize_mat(&w, 4);
        for i in 0..q.rows {
            let mut vals: Vec<i64> = q.row(i).iter().map(|v| (v * 1e4).round() as i64).collect();
            vals.sort_unstable();
            vals.dedup();
            assert!(vals.len() <= 16);
        }
    }

    #[test]
    fn prop_omniquant_qmat_bit_identical_to_pre_refactor() {
        use crate::util::propcheck::{gen, Runner};
        Runner::new().cases(20).run("omniquant QMat bit-identity", |rng| {
            let r = gen::size(rng, 1, 6);
            let c = gen::size(rng, 4, 64);
            let bits = [2u8, 4, 8][rng.below(3)];
            let w = Mat::from_vec(r, c, gen::vec_f32(rng, r * c));
            let q = omniquant_quantize_qmat(&w, bits);
            if q.dequantize().data == pre_refactor_omniquant(&w, bits).data {
                Ok(())
            } else {
                Err(format!("omniquant mismatch at {bits} bits, shape {r}x{c}"))
            }
        });
    }

    #[test]
    fn packed_model_matches_dense() {
        let cfg = crate::model::ModelConfig::builtin("llama2-tiny").unwrap();
        let w = Weights::default_synthetic(&cfg, 4);
        let dense = omniquant_quantize_model(&w, 4);
        let packed = omniquant_quantize_model_packed(&w, 4);
        assert!(packed.has_packed());
        assert!(packed.nbytes() < dense.nbytes());
        assert_eq!(packed.tensor("l0.wd").to_mat().data, dense.get("l0.wd").data);
    }
}
