//! GPTQ weight reconstruction (Frantar et al.) — the paper applies GPTQ on
//! top of the rotated weights for the main results.
//!
//! Per linear layer with input activations X (calibration):
//!   H = 2·XᵀX + λI  (dampened Hessian)
//! then quantize weight columns left-to-right, distributing each column's
//! rounding error over the not-yet-quantized columns via H⁻¹ (Cholesky
//! form). This is the standard "act-order off, no grouping" GPTQ, scaled
//! to our matrix sizes.

use super::{snap, wide_qmax};
use crate::linalg::cholesky;
use crate::model::{CaptureHook, FwdOptions, Weights};
use crate::tensor::{Mat, QMat, QuantSpec};

/// GPTQ hyper-parameters.
#[derive(Clone, Copy, Debug)]
pub struct GptqConfig {
    pub bits: u8,
    /// Relative dampening λ = damp · mean(diag(H)).
    pub damp: f32,
}

impl Default for GptqConfig {
    fn default() -> Self {
        GptqConfig { bits: 4, damp: 0.01 }
    }
}

/// The shared per-layer GPTQ setup: dampened Hessian → Cholesky factor
/// of its inverse, plus the per-row symmetric scales from the original
/// weights. Split out of [`gptq_propagate`] so the coordinator can run
/// it **once** per layer and fan the row-independent propagation
/// ([`gptq_propagate_rows`]) out over `--shards` sub-jobs.
pub(crate) fn gptq_prepare(w: &Mat, hessian: &Mat, cfg: GptqConfig) -> (Mat, Vec<f32>) {
    assert_eq!(hessian.rows, w.cols);
    let n = w.cols;
    let qmax = wide_qmax(cfg.bits);

    // Dampened Hessian.
    let mut h = hessian.clone();
    let mean_diag: f32 = (0..n).map(|i| h.at(i, i)).sum::<f32>() / n as f32;
    let lambda = cfg.damp * mean_diag.max(1e-8);
    for i in 0..n {
        *h.at_mut(i, i) += lambda;
    }

    // Cholesky of the INVERSE Hessian, upper form (the standard GPTQ
    // trick): Hinv = Uᵀ U with U upper triangular; the error propagation
    // uses rows of U.
    let hinv = crate::linalg::cholesky_inverse(&h).expect("dampened Hessian SPD");
    // Upper-triangular factor of Hinv via Cholesky of the reversed matrix:
    // we need U with Hinv = UᵀU... equivalently L from cholesky(Hinv)
    // gives Hinv = LLᵀ; GPTQ uses the upper Cholesky of Hinv. Take
    // U = chol(Hinv reversed) trick — or simply use L of Hinv directly
    // with the column loop adapted (we propagate with L's columns).
    let l = cholesky(&hinv).expect("Hinv SPD");

    // Per-row symmetric scale from the original weights.
    let scales: Vec<f32> = (0..w.rows)
        .map(|i| {
            let amax = w.row(i).iter().map(|v| v.abs()).fold(0.0f32, f32::max);
            (amax / qmax).max(1e-10)
        })
        .collect();
    (l, scales)
}

/// Column-by-column quantize + error propagation restricted to weight
/// rows `[lo, hi)`:
///   e_j = (w_j - q_j) / L[j][j];  w_k -= e_j * L[k][j]  for k > j.
/// Each row's updates read and write only that row, so a row-range
/// decomposition replays the exact per-row operation sequence of the
/// whole-matrix loop — stitching the blocks back in order is
/// bit-identical at any shard count. This is the `--shards` sub-job unit.
pub(crate) fn gptq_propagate_rows(
    w: &Mat,
    l: &Mat,
    scales: &[f32],
    cfg: GptqConfig,
    lo: usize,
    hi: usize,
) -> Mat {
    let n = w.cols;
    let qmax = wide_qmax(cfg.bits);
    let mut out = Mat::from_fn(hi - lo, n, |r, j| w.at(lo + r, j));
    for j in 0..n {
        let ljj = l.at(j, j).max(1e-10);
        for r in 0..out.rows {
            let v = out.at(r, j);
            let q = snap(v, scales[lo + r], qmax);
            *out.at_mut(r, j) = q;
            let e = (v - q) / ljj;
            if e != 0.0 {
                for k in (j + 1)..n {
                    let lkj = l.at(k, j);
                    if lkj != 0.0 {
                        *out.at_mut(r, k) -= e * lkj;
                    }
                }
            }
        }
    }
    out
}

/// The GPTQ core: column-by-column quantize with Cholesky error
/// propagation. Returns the propagated working matrix (entries on or near
/// the per-row grid) plus the per-row scales — callers snap it onto the
/// grid as packed codes ([`gptq_quantize_layer_qmat`]) or dense f32
/// ([`gptq_quantize_layer`]).
fn gptq_propagate(w: &Mat, hessian: &Mat, cfg: GptqConfig) -> (Mat, Vec<f32>) {
    let (l, scales) = gptq_prepare(w, hessian, cfg);
    let out = gptq_propagate_rows(w, &l, &scales, cfg, 0, w.rows);
    (out, scales)
}

/// The wide-grid (non-packing bits) tail of [`gptq_quantize_layer`]:
/// snap the propagated values onto the per-row f32 grid in place. Split
/// out so the coordinator's sharded path finalizes stitched row blocks
/// with the identical expression.
pub(crate) fn gptq_snap_wide(out: &mut Mat, scales: &[f32], bits: u8) {
    let qmax = wide_qmax(bits);
    for i in 0..out.rows {
        let s = scales[i];
        for v in out.row_mut(i) {
            *v = snap(*v, s, qmax);
        }
    }
}

/// GPTQ into packed codes: the final grid snap becomes the QMat encode
/// on the propagated working matrix (bits ∈ [2, 8]).
pub fn gptq_quantize_layer_qmat(w: &Mat, hessian: &Mat, cfg: GptqConfig) -> QMat {
    let (working, scales) = gptq_propagate(w, hessian, cfg);
    let q = QMat::quantize_with_scales(&working, QuantSpec::new(cfg.bits), scales);
    q.prepack();
    q
}

/// Quantize one weight matrix ([out, in]) given the layer's input Hessian
/// H = XᵀX (in-dim × in-dim). Returns the dequantized reconstruction.
pub fn gptq_quantize_layer(w: &Mat, hessian: &Mat, cfg: GptqConfig) -> Mat {
    if cfg.bits >= 16 {
        return w.clone();
    }
    if QuantSpec::supports(cfg.bits) {
        return gptq_quantize_layer_qmat(w, hessian, cfg).dequantize();
    }
    // Wide grids: snap the propagated values onto the f32 grid directly.
    let (mut out, scales) = gptq_propagate(w, hessian, cfg);
    gptq_snap_wide(&mut out, &scales, cfg.bits);
    out
}

/// Hessian accumulator hook for the native forward.
struct HessianHook {
    names: Vec<String>,
    hessians: std::collections::BTreeMap<String, Mat>,
}

impl CaptureHook for HessianHook {
    fn on_linear_input(&mut self, name: &str, x: &Mat) {
        if !self.names.iter().any(|n| n == name) {
            return;
        }
        let h = self
            .hessians
            .entry(name.to_string())
            .or_insert_with(|| Mat::zeros(x.cols, x.cols));
        // H += XᵀX (accumulated across batches).
        let xtx = crate::tensor::matmul(&x.t(), x);
        h.add_assign(&xtx);
    }
}

/// GPTQ over every transformer linear of a model, capturing Hessians from
/// `calib_seqs` via the native forward. Quantizes in place of RTN.
pub fn gptq_quantize_model(weights: &Weights, calib_seqs: &[Vec<i32>], cfg: GptqConfig) -> Weights {
    gptq_quantize_model_with(weights, calib_seqs, cfg, false)
}

/// [`gptq_quantize_model`] with packed storage: every reconstructed
/// linear lands as a [`QMat`]. Falls back to the dense model when
/// `cfg.bits` doesn't pack.
pub fn gptq_quantize_model_packed(
    weights: &Weights,
    calib_seqs: &[Vec<i32>],
    cfg: GptqConfig,
) -> Weights {
    gptq_quantize_model_with(weights, calib_seqs, cfg, QuantSpec::supports(cfg.bits))
}

/// [`gptq_quantize_model`] over a `model::WeightStore` — the streamed
/// pipeline's GPTQ. The layer-at-a-time forward (`model::stream_blocks`)
/// accumulates each layer's input Hessians and quantizes that layer in
/// place before moving on, so at most one layer's weights + Hessians are
/// resident. Two facts make the output **bit-identical** to the
/// in-memory pass: per-linear Hessian contributions arrive in the same
/// sequence order (f32 accumulation order preserved), and every
/// captured input comes from the *original* weights — `stream_blocks`
/// advances the residuals through a layer before `after_layer`
/// quantizes it, exactly mirroring the in-memory capture-then-quantize
/// split. See `docs/STREAMING.md`.
pub fn gptq_quantize_store(
    store: &crate::model::WeightStore,
    calib_seqs: &[Vec<i32>],
    cfg: GptqConfig,
    packed: bool,
) -> anyhow::Result<()> {
    let packed = packed && QuantSpec::supports(cfg.bits);
    let mut names = Vec::new();
    for l in 0..store.cfg().n_layers {
        for leaf in ["wq", "wo", "wg", "wd"] {
            names.push(format!("l{l}.{leaf}"));
        }
    }
    let mut hook = HessianHook { names, hessians: Default::default() };
    crate::model::stream_blocks(store, calib_seqs, FwdOptions::FP, &mut hook, |l, hook, lease| {
        let sites = [
            (format!("l{l}.wq"), vec![format!("l{l}.wq"), format!("l{l}.wk"), format!("l{l}.wv")]),
            (format!("l{l}.wo"), vec![format!("l{l}.wo")]),
            (format!("l{l}.wg"), vec![format!("l{l}.wg"), format!("l{l}.wu")]),
            (format!("l{l}.wd"), vec![format!("l{l}.wd")]),
        ];
        let w = lease.weights_mut();
        for (site, targets) in sites {
            // Drop the layer's Hessians as we consume them: only the
            // current layer's capture state is ever resident.
            let Some(h) = hook.hessians.remove(&site) else { continue };
            for t in targets {
                if packed {
                    let q = gptq_quantize_layer_qmat(w.get(&t), &h, cfg);
                    w.set_packed(&t, q);
                } else {
                    let q = gptq_quantize_layer(w.get(&t), &h, cfg);
                    w.set(&t, q);
                }
            }
        }
        Ok(())
    })
}

/// Accumulate per-site input Hessians over `calib_seqs` via the native
/// forward. The capture hook reports wq (shared input with wk/wv), wo,
/// wg (shared with wu), wd — covering every linear's input. Sequential
/// by construction: f32 `H += XᵀX` accumulation order is part of the
/// determinism contract, so `--shards` never decomposes this step.
pub(crate) fn gptq_capture_hessians(
    weights: &Weights,
    calib_seqs: &[Vec<i32>],
) -> std::collections::BTreeMap<String, Mat> {
    let mut names = Vec::new();
    for l in 0..weights.cfg.n_layers {
        for leaf in ["wq", "wo", "wg", "wd"] {
            names.push(format!("l{l}.{leaf}"));
        }
    }
    let mut hook = HessianHook { names, hessians: Default::default() };
    for seq in calib_seqs {
        crate::model::forward_one(weights, seq, FwdOptions::FP, &mut hook);
    }
    hook.hessians
}

/// Layer `l`'s Hessian capture sites and the quantization targets that
/// share each site's input.
pub(crate) fn gptq_sites(l: usize) -> [(String, Vec<String>); 4] {
    [
        (format!("l{l}.wq"), vec![format!("l{l}.wq"), format!("l{l}.wk"), format!("l{l}.wv")]),
        (format!("l{l}.wo"), vec![format!("l{l}.wo")]),
        (format!("l{l}.wg"), vec![format!("l{l}.wg"), format!("l{l}.wu")]),
        (format!("l{l}.wd"), vec![format!("l{l}.wd")]),
    ]
}

fn gptq_quantize_model_with(
    weights: &Weights,
    calib_seqs: &[Vec<i32>],
    cfg: GptqConfig,
    packed: bool,
) -> Weights {
    let hessians = gptq_capture_hessians(weights, calib_seqs);
    let mut out = weights.clone();
    for l in 0..weights.cfg.n_layers {
        for (site, targets) in gptq_sites(l) {
            let Some(h) = hessians.get(&site) else { continue };
            for t in targets {
                if packed {
                    let q = gptq_quantize_layer_qmat(out.get(&t), h, cfg);
                    out.set_packed(&t, q);
                } else {
                    let q = gptq_quantize_layer(out.get(&t), h, cfg);
                    out.set(&t, q);
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{rtn_mse, rtn_quantize_mat};
    use crate::util::prng::Pcg64;

    /// Correlated activations (the regime where GPTQ beats RTN).
    fn correlated_acts(rng: &mut Pcg64, t: usize, n: usize) -> Mat {
        let base = Mat::from_fn(t, n / 4, |_, _| rng.normal());
        Mat::from_fn(t, n, |i, j| {
            base.at(i, j % base.cols) + 0.3 * rng.normal()
        })
    }

    fn recon_err(w: &Mat, q: &Mat, x: &Mat) -> f64 {
        // ‖X(W-Q)ᵀ‖² — the objective GPTQ minimizes.
        let d = w.sub(q);
        let y = crate::tensor::matmul_transb(x, &d);
        y.data.iter().map(|v| (*v as f64).powi(2)).sum()
    }

    #[test]
    fn gptq_beats_rtn_on_correlated_inputs() {
        let mut rng = Pcg64::new(1);
        let n = 64;
        let x = correlated_acts(&mut rng, 256, n);
        let h = crate::tensor::matmul(&x.t(), &x);
        let w = Mat::from_fn(16, n, |_, _| rng.normal());
        let cfg = GptqConfig { bits: 4, damp: 0.01 };
        let q_gptq = gptq_quantize_layer(&w, &h, cfg);
        let q_rtn = rtn_quantize_mat(&w, 4);
        let e_gptq = recon_err(&w, &q_gptq, &x);
        let e_rtn = recon_err(&w, &q_rtn, &x);
        assert!(
            e_gptq < e_rtn * 0.9,
            "GPTQ should beat RTN on correlated inputs: {e_gptq} vs {e_rtn}"
        );
    }

    #[test]
    fn gptq_output_is_on_grid() {
        let mut rng = Pcg64::new(2);
        let n = 32;
        let x = correlated_acts(&mut rng, 64, n);
        let h = crate::tensor::matmul(&x.t(), &x);
        let w = Mat::from_fn(4, n, |_, _| rng.normal());
        let q = gptq_quantize_layer(&w, &h, GptqConfig::default());
        for i in 0..q.rows {
            let mut vals: Vec<i64> = q.row(i).iter().map(|v| (v * 1e4).round() as i64).collect();
            vals.sort_unstable();
            vals.dedup();
            assert!(vals.len() <= 16, "row {i}: {} levels", vals.len());
        }
    }

    #[test]
    fn gptq_16bit_is_identity() {
        let mut rng = Pcg64::new(3);
        let w = Mat::from_fn(4, 16, |_, _| rng.normal());
        let h = Mat::eye(16);
        let q = gptq_quantize_layer(&w, &h, GptqConfig { bits: 16, damp: 0.01 });
        assert_eq!(q, w);
    }

    #[test]
    fn gptq_with_identity_hessian_matches_rtn_error_scale() {
        // With H = I there is no correlation to exploit; GPTQ ≈ RTN.
        let mut rng = Pcg64::new(4);
        let w = Mat::from_fn(8, 32, |_, _| rng.normal());
        let q = gptq_quantize_layer(&w, &Mat::eye(32), GptqConfig::default());
        let mse: f64 = w
            .data
            .iter()
            .zip(&q.data)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            / w.data.len() as f64;
        assert!(mse < rtn_mse(&w, 4) * 2.5, "{mse} vs rtn {}", rtn_mse(&w, 4));
    }

    /// Verbatim pre-refactor GPTQ layer (inline snap + final snap) — the
    /// oracle for the QMat bit-identity property test.
    fn pre_refactor_gptq(w: &Mat, hessian: &Mat, cfg: GptqConfig) -> Mat {
        let n = w.cols;
        let qmax = ((1i32 << (cfg.bits - 1)) - 1) as f32;
        let mut h = hessian.clone();
        let mean_diag: f32 = (0..n).map(|i| h.at(i, i)).sum::<f32>() / n as f32;
        let lambda = cfg.damp * mean_diag.max(1e-8);
        for i in 0..n {
            *h.at_mut(i, i) += lambda;
        }
        let hinv = crate::linalg::cholesky_inverse(&h).expect("dampened Hessian SPD");
        let l = crate::linalg::cholesky(&hinv).expect("Hinv SPD");
        let mut out = w.clone();
        let scales: Vec<f32> = (0..w.rows)
            .map(|i| {
                let amax = w.row(i).iter().map(|v| v.abs()).fold(0.0f32, f32::max);
                (amax / qmax).max(1e-10)
            })
            .collect();
        for j in 0..n {
            let ljj = l.at(j, j).max(1e-10);
            for i in 0..w.rows {
                let v = out.at(i, j);
                let q = (v / scales[i]).round().clamp(-qmax - 1.0, qmax) * scales[i];
                *out.at_mut(i, j) = q;
                let e = (v - q) / ljj;
                if e != 0.0 {
                    for k in (j + 1)..n {
                        let lkj = l.at(k, j);
                        if lkj != 0.0 {
                            *out.at_mut(i, k) -= e * lkj;
                        }
                    }
                }
            }
        }
        for i in 0..out.rows {
            let s = scales[i];
            for v in out.row_mut(i) {
                *v = (*v / s).round().clamp(-qmax - 1.0, qmax) * s;
            }
        }
        out
    }

    #[test]
    fn prop_gptq_qmat_bit_identical_to_pre_refactor() {
        use crate::util::propcheck::{gen, Runner};
        Runner::new().cases(12).run("gptq QMat bit-identity", |rng| {
            let r = gen::size(rng, 1, 6);
            let n = gen::size(rng, 4, 32);
            let bits = [2u8, 4, 8][rng.below(3)];
            let w = Mat::from_vec(r, n, gen::vec_f32(rng, r * n));
            let x = Mat::from_vec(3 * n, n, gen::vec_f32(rng, 3 * n * n));
            let h = crate::tensor::matmul(&x.t(), &x);
            let cfg = GptqConfig { bits, damp: 0.01 };
            let q = gptq_quantize_layer_qmat(&w, &h, cfg);
            if q.nbytes() >= q.dense_nbytes() {
                return Err("no packing win".into());
            }
            if q.dequantize().data == pre_refactor_gptq(&w, &h, cfg).data {
                Ok(())
            } else {
                Err(format!("gptq mismatch at {bits} bits, shape {r}x{n}"))
            }
        });
    }

    #[test]
    fn gptq_packed_model_matches_dense() {
        let cfg = crate::model::ModelConfig::builtin("llama2-tiny").unwrap();
        let corpus = crate::data::Corpus::new(crate::data::Dialect::Wiki, cfg.vocab, 7);
        let w = Weights::default_grammar(&cfg, 1, corpus.successor()).unwrap();
        let calib = corpus.calib_sequences(2, 32);
        let dense = gptq_quantize_model(&w, &calib, GptqConfig::default());
        let packed = gptq_quantize_model_packed(&w, &calib, GptqConfig::default());
        assert!(packed.has_packed());
        assert!(packed.nbytes() < dense.nbytes());
        assert_eq!(packed.tensor("l0.wq").to_mat().data, dense.get("l0.wq").data);
    }

    #[test]
    fn gptq_model_runs_and_changes_linears_only() {
        let cfg = crate::model::ModelConfig::builtin("llama2-tiny").unwrap();
        let corpus = crate::data::Corpus::new(crate::data::Dialect::Wiki, cfg.vocab, 7);
        let w = Weights::default_grammar(&cfg, 1, corpus.successor()).unwrap();
        let calib = corpus.calib_sequences(2, 32);
        let q = gptq_quantize_model(&w, &calib, GptqConfig::default());
        assert_eq!(q.get("embed").data, w.get("embed").data);
        assert_ne!(q.get("l0.wq").data, w.get("l0.wq").data);
    }
}
