//! Memory-budgeted admission control — the mechanism behind the paper's
//! "70B on a single RTX 3090" claim (Table 3's DartQuant₃₀₉₀ rows).
//!
//! Every calibration job declares its peak resident bytes; the gate admits
//! jobs while the sum stays under the budget, blocking others until
//! capacity frees up. A job larger than the whole budget is rejected
//! outright — which is exactly what happens to end-to-end fine-tuning
//! (SpinQuant/OSTQuant hold model + optimizer + backprop state) on a
//! 24 GiB card, while DartQuant's per-rotation jobs stream through.
//!
//! The parallel scheduler ([`super::Scheduler`]) admits every job here
//! before it runs, so the budget — not the worker count — bounds
//! in-flight activation state; see `docs/CONCURRENCY.md`. The same gate
//! type backs the out-of-core weight store (`model::WeightStore`): every
//! checkout lease charges its decoded weight bytes, so a streamed run's
//! store never holds more than `--resident-budget` checked out at once
//! (what the budget does and does not bound is spelled out in
//! `docs/STREAMING.md`).

use crate::util::mem::PeakTracker;
use crate::util::sync::{lock_or_poisoned, wait_or_poisoned};
use std::sync::{Arc, Condvar, Mutex};

/// Byte-denominated admission gate with peak tracking.
pub struct MemoryGate {
    budget: Option<u64>,
    state: Mutex<u64>, // bytes in flight
    cv: Condvar,
    tracker: PeakTracker,
}

/// Error for jobs that can never fit.
#[derive(Debug, thiserror::Error)]
#[error("job needs {need} bytes but the memory budget is {budget} — the paper's e2e fine-tuning hits exactly this wall on a 24 GiB card")]
pub struct OverBudget {
    pub need: u64,
    pub budget: u64,
}

impl MemoryGate {
    /// A gate with `budget` bytes of capacity (`None` = unlimited, the
    /// gate still tracks peaks).
    pub fn new(budget: Option<u64>) -> MemoryGate {
        MemoryGate {
            budget,
            state: Mutex::new(0),
            cv: Condvar::new(),
            tracker: PeakTracker::new(),
        }
    }

    /// The paper's single-3090 setting scaled to our substrate: the 70B
    /// stand-in is ~1000× smaller than the real model, so 24 GiB scales to
    /// 24 MiB of job-resident calibration state.
    pub fn scaled_3090() -> MemoryGate {
        MemoryGate::new(Some(24 << 20))
    }

    /// The configured budget in bytes (`None` = unlimited).
    pub fn budget(&self) -> Option<u64> {
        self.budget
    }

    /// Block until `bytes` fit under the budget; returns a guard that
    /// releases on drop. Errors if `bytes` exceeds the whole budget.
    pub fn admit(&self, bytes: u64) -> Result<MemoryLease<'_>, OverBudget> {
        // The tracker is charged while the admission lock is held (and
        // discharged before capacity is released) so peak_bytes() can
        // never observe more than the budget.
        let charge;
        if let Some(b) = self.budget {
            if bytes > b {
                return Err(OverBudget { need: bytes, budget: b });
            }
            let mut used = lock_or_poisoned(&self.state);
            while *used + bytes > b {
                used = wait_or_poisoned(&self.cv, used);
            }
            *used += bytes;
            charge = self.tracker.charge(bytes);
        } else {
            let mut used = lock_or_poisoned(&self.state);
            *used += bytes;
            charge = self.tracker.charge(bytes);
        }
        Ok(MemoryLease { gate: self, bytes, charge: Some(charge) })
    }

    /// Non-blocking admission for continuous batching (the serving
    /// engine admits sessions *between* decode steps): `Ok(Some)` when
    /// `bytes` fit right now, `Ok(None)` when they would fit but the
    /// capacity is currently leased, `Err(OverBudget)` when they can
    /// never fit. Unlimited gates always admit. An associated function
    /// because the returned lease keeps the gate alive via `Arc`, so
    /// long-lived holders can store it without borrowing.
    pub fn try_admit_owned(
        gate: &Arc<MemoryGate>,
        bytes: u64,
    ) -> Result<Option<OwnedLease>, OverBudget> {
        let mut used = lock_or_poisoned(&gate.state);
        if let Some(b) = gate.budget {
            if bytes > b {
                return Err(OverBudget { need: bytes, budget: b });
            }
            if *used + bytes > b {
                return Ok(None);
            }
        }
        *used += bytes;
        let charge = gate.tracker.charge(bytes);
        drop(used);
        Ok(Some(OwnedLease { gate: Arc::clone(gate), bytes, charge: Some(charge) }))
    }

    /// Peak bytes admitted simultaneously over the gate's lifetime.
    pub fn peak_bytes(&self) -> u64 {
        self.tracker.peak_bytes()
    }

    /// Bytes currently admitted (live leases). The out-of-core
    /// `model::WeightStore` exposes this as its exact resident-weight
    /// accounting — see `docs/STREAMING.md`.
    pub fn current_bytes(&self) -> u64 {
        self.tracker.current_bytes()
    }
}

/// RAII admission lease.
pub struct MemoryLease<'a> {
    gate: &'a MemoryGate,
    bytes: u64,
    charge: Option<crate::util::mem::ChargeGuard>,
}

impl Drop for MemoryLease<'_> {
    fn drop(&mut self) {
        let mut used = lock_or_poisoned(&self.gate.state);
        self.charge.take(); // discharge the tracker before freeing capacity
        *used -= self.bytes;
        drop(used);
        self.gate.cv.notify_all();
    }
}

/// Owned admission lease ([`MemoryGate::try_admit_owned`]): identical
/// release semantics to [`MemoryLease`], but keeps the gate alive via
/// `Arc` so serving sessions can carry their lease across engine steps.
pub struct OwnedLease {
    gate: Arc<MemoryGate>,
    bytes: u64,
    charge: Option<crate::util::mem::ChargeGuard>,
}

impl OwnedLease {
    /// Bytes this lease holds against the gate.
    pub fn bytes(&self) -> u64 {
        self.bytes
    }
}

impl Drop for OwnedLease {
    fn drop(&mut self) {
        let mut used = lock_or_poisoned(&self.gate.state);
        self.charge.take(); // discharge the tracker before freeing capacity
        *used -= self.bytes;
        drop(used);
        self.gate.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::Arc;

    #[test]
    fn unlimited_gate_admits_everything() {
        let g = MemoryGate::new(None);
        let _a = g.admit(u64::MAX / 4).unwrap();
        let _b = g.admit(u64::MAX / 4).unwrap();
        assert!(g.peak_bytes() >= u64::MAX / 4);
    }

    #[test]
    fn oversized_job_is_rejected() {
        let g = MemoryGate::new(Some(100));
        let Err(err) = g.admit(101) else { panic!("expected rejection") };
        assert_eq!(err.need, 101);
        assert!(g.admit(100).is_ok());
    }

    #[test]
    fn budget_is_never_exceeded_under_concurrency() {
        let g = Arc::new(MemoryGate::new(Some(100)));
        let max_seen = Arc::new(AtomicU64::new(0));
        let cur = Arc::new(AtomicU64::new(0));
        std::thread::scope(|s| {
            for _ in 0..8 {
                let g = Arc::clone(&g);
                let max_seen = Arc::clone(&max_seen);
                let cur = Arc::clone(&cur);
                s.spawn(move || {
                    for _ in 0..50 {
                        let lease = g.admit(30).unwrap();
                        let now = cur.fetch_add(30, Ordering::SeqCst) + 30;
                        max_seen.fetch_max(now, Ordering::SeqCst);
                        std::thread::yield_now();
                        cur.fetch_sub(30, Ordering::SeqCst);
                        drop(lease);
                    }
                });
            }
        });
        assert!(max_seen.load(Ordering::SeqCst) <= 90, "gate leaked");
        assert!(g.peak_bytes() <= 90);
    }

    #[test]
    fn current_bytes_tracks_live_leases() {
        let g = MemoryGate::new(Some(100));
        assert_eq!(g.current_bytes(), 0);
        let a = g.admit(40).unwrap();
        let b = g.admit(30).unwrap();
        assert_eq!(g.current_bytes(), 70);
        drop(a);
        assert_eq!(g.current_bytes(), 30);
        drop(b);
        assert_eq!(g.current_bytes(), 0);
        assert_eq!(g.peak_bytes(), 70, "peak survives release");
    }

    #[test]
    fn scaled_3090_has_24_mib() {
        assert_eq!(MemoryGate::scaled_3090().budget(), Some(24 << 20));
    }

    #[test]
    fn try_admit_owned_is_non_blocking_and_releases_on_drop() {
        let g = Arc::new(MemoryGate::new(Some(100)));
        assert!(MemoryGate::try_admit_owned(&g, 101).is_err(), "can never fit");
        let a = MemoryGate::try_admit_owned(&g, 60).unwrap().expect("fits");
        assert_eq!(a.bytes(), 60);
        // Would fit an empty gate, but capacity is leased right now.
        assert!(MemoryGate::try_admit_owned(&g, 60).unwrap().is_none());
        drop(a);
        let b = MemoryGate::try_admit_owned(&g, 60).unwrap();
        assert!(b.is_some(), "capacity freed by drop");
        assert_eq!(g.peak_bytes(), 60);
    }

    #[test]
    fn try_admit_owned_unlimited_always_admits() {
        let g = Arc::new(MemoryGate::new(None));
        let a = MemoryGate::try_admit_owned(&g, u64::MAX / 4).unwrap();
        assert!(a.is_some());
        assert!(g.peak_bytes() >= u64::MAX / 4);
    }
}
