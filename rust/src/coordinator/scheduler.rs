//! Parallel per-layer calibration scheduling.
//!
//! DartQuant's headline property is that rotational calibration is
//! *local*: the R1 problem and every layer's R2/QR-Orth problem are
//! independent of each other (that locality is what buys the paper's 47×
//! speedup and 10× memory saving over end-to-end fine-tuning). The
//! [`Scheduler`] exploits it: a stage decomposes into [`CalibJob`]s, the
//! scheduler fans them out over `workers` threads
//! ([`crate::util::threadpool::scoped_try_map`]), and joins the results
//! in job order.
//!
//! Three invariants make parallel runs indistinguishable from serial
//! ones (the determinism contract, see `docs/CONCURRENCY.md`):
//!
//! 1. **Per-job seeding** — every job derives its PRNG seed as
//!    `base ⊕ id` ([`CalibJob::seed`]), never from shared mutable state,
//!    so results are bit-identical at any worker count.
//! 2. **Ordered event delivery** — jobs emit [`PipelineEvent`]s into a
//!    per-job [`JobSink`]; the scheduler replays all buffered events to
//!    the observer in job-id order *after* the join, so observers see the
//!    same stream regardless of completion order.
//! 3. **Budget admission** — each job's declared bytes are admitted
//!    against the run's [`MemoryGate`] before it executes, bounding
//!    in-flight activation state; an over-budget scheduler simply
//!    degrades to fewer jobs in flight (worst case: serial).
//!
//! Failures keep their locus: a job that returns an error (or panics on
//! a worker) fails the run with the job's id and label in the error
//! chain, after the surviving jobs have drained.
//!
//! **Streamed (out-of-core) runs** compose a second gate with this one:
//! a job body may check weights out of a `model::WeightStore`, whose own
//! `MemoryGate` charges decoded weight bytes against the resident
//! budget. Every job acquires in the same order — job gate first (before
//! the runner), then weight leases inside the runner — and releases in
//! reverse, so the two semaphore-style gates cannot deadlock; a tight
//! resident budget simply serializes the weight checkouts while the job
//! gate still bounds activation state. Job ids, labels and declared
//! bytes are identical between streamed and in-memory runs, so the
//! ordered event stream does not change (`docs/STREAMING.md` spells out
//! the full canonical-report contract and its capture-backend
//! carve-out).

use super::budget::MemoryGate;
use super::report::{PipelineEvent, PipelineObserver};
use crate::util::threadpool::{self, ThreadPool};
use anyhow::{Context, Result};
use std::time::Instant;

/// One independent unit of calibrate- or quantize-stage work: an id that
/// fixes its position in the deterministic ordering, a label for events
/// and errors, a byte declaration for the memory gate, and a
/// strategy-specific payload.
pub struct CalibJob<P> {
    /// Stable job id. Convention for rotation calibration: `0` is the R1
    /// (global) job, `l + 1` is layer `l`'s R2 job.
    pub id: usize,
    /// Human-readable label used in [`PipelineEvent::JobStarted`] and in
    /// error contexts (e.g. `"r1"`, `"r2[3]"`, `"omniquant[l2]"`).
    pub label: String,
    /// Declared peak resident bytes, admitted against the [`MemoryGate`]
    /// before the job runs.
    pub bytes: u64,
    /// Whatever the runner needs: activation pool + calibration config,
    /// weight-matrix names, …
    pub payload: P,
}

impl<P> CalibJob<P> {
    /// Build a job.
    pub fn new(id: usize, label: impl Into<String>, bytes: u64, payload: P) -> CalibJob<P> {
        CalibJob { id, label: label.into(), bytes, payload }
    }

    /// Deterministic per-job PRNG seed: `base ⊕ id`. Jobs must draw all
    /// their randomness from a generator seeded this way (never from
    /// shared state), which is what makes parallel and serial runs
    /// bit-identical.
    pub fn seed(&self, base: u64) -> u64 {
        base ^ self.id as u64
    }
}

/// Buffered event sink handed to a running job. Events accumulate here
/// (on the worker thread, no locks) and are replayed to the pipeline
/// observer in job-id order once every job has joined.
pub struct JobSink {
    events: Vec<PipelineEvent>,
}

impl JobSink {
    fn new() -> JobSink {
        JobSink { events: Vec::new() }
    }

    /// Buffer an event for ordered delivery after the join.
    pub fn emit(&mut self, event: PipelineEvent) {
        self.events.push(event);
    }
}

/// Executes [`CalibJob`]s across worker threads under a memory gate, with
/// deterministic result and event ordering. Construct one per stage from
/// the pipeline's worker setting ([`Scheduler::new`]).
pub struct Scheduler {
    workers: usize,
}

impl Scheduler {
    /// A scheduler with `workers` threads; `0` means the machine's
    /// available parallelism (the `PipelineConfig::workers` convention).
    pub fn new(workers: usize) -> Scheduler {
        let workers = if workers == 0 { ThreadPool::default_parallelism() } else { workers };
        Scheduler { workers }
    }

    /// The resolved worker count.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run every job, returning their outputs **in job (submission)
    /// order** regardless of completion order.
    ///
    /// Per job, the scheduler: buffers a [`PipelineEvent::JobStarted`],
    /// blocks until the gate admits `job.bytes` (buffering
    /// [`PipelineEvent::JobAdmitted`]), invokes `runner(job, sink)`,
    /// releases the gate lease, and buffers a
    /// [`PipelineEvent::JobFinished`] with the job's wall clock (gate
    /// wait included). After all jobs join, buffered events replay to
    /// `observer` in job order — the ordered-delivery half of the
    /// determinism contract.
    ///
    /// Errors: a job whose `runner` returns `Err` (or whose declared
    /// bytes exceed the whole budget) fails the run with the job id +
    /// label in the context chain; when several fail, the earliest in
    /// submission order wins (= lowest id for the built-in ascending
    /// decompositions), and events are still delivered first. A job that
    /// *panics* fails the run the same way but without event delivery
    /// (the panicking sink's buffer is lost mid-flight).
    pub fn run<P, T, F>(
        &self,
        gate: &MemoryGate,
        observer: &dyn PipelineObserver,
        jobs: Vec<CalibJob<P>>,
        runner: F,
    ) -> Result<Vec<T>>
    where
        P: Sync,
        T: Send,
        F: Fn(&CalibJob<P>, &mut JobSink) -> Result<T> + Sync,
    {
        let outcomes = threadpool::scoped_try_map(self.workers, &jobs, |_, job| {
            let mut sink = JobSink::new();
            let t0 = Instant::now();
            sink.emit(PipelineEvent::JobStarted { job: job.id, label: job.label.clone() });
            let result = match gate.admit(job.bytes) {
                Ok(_lease) => {
                    sink.emit(PipelineEvent::JobAdmitted { job: job.id, bytes: job.bytes });
                    runner(job, &mut sink)
                    // _lease drops here: capacity frees only after the job
                    // is done with its activation state.
                }
                Err(over) => Err(anyhow::Error::new(over)),
            };
            sink.emit(PipelineEvent::JobFinished {
                job: job.id,
                elapsed: t0.elapsed(),
                ok: result.is_ok(),
            });
            (sink.events, result)
        })
        .map_err(|p| {
            let (id, label) = (jobs[p.index].id, jobs[p.index].label.clone());
            anyhow::anyhow!("calibration job {id} ({label}) panicked: {}", p.message)
        })?;

        // Ordered delivery: replay every job's buffered events in job
        // order, only now that the join is complete.
        for (events, _) in &outcomes {
            for e in events {
                observer.on_event(e);
            }
        }
        let mut out = Vec::with_capacity(outcomes.len());
        for ((_, result), job) in outcomes.into_iter().zip(&jobs) {
            let v = result
                .with_context(|| format!("calibration job {} ({}) failed", job.id, job.label))?;
            out.push(v);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::report::{CollectingObserver, NullObserver};
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn unit_jobs(n: usize, bytes: u64) -> Vec<CalibJob<()>> {
        (0..n).map(|i| CalibJob::new(i, format!("j{i}"), bytes, ())).collect()
    }

    #[test]
    fn results_arrive_in_job_order() {
        let gate = MemoryGate::new(None);
        let sched = Scheduler::new(4);
        let out = sched
            .run(&gate, &NullObserver, unit_jobs(16, 1), |job, _| Ok(job.id * 10))
            .unwrap();
        assert_eq!(out, (0..16).map(|i| i * 10).collect::<Vec<_>>());
    }

    #[test]
    fn zero_workers_means_available_parallelism() {
        assert_eq!(Scheduler::new(0).workers(), ThreadPool::default_parallelism());
        assert_eq!(Scheduler::new(3).workers(), 3);
    }

    #[test]
    fn events_replay_in_job_order_at_any_worker_count() {
        let streams: Vec<Vec<(usize, bool)>> = [1usize, 4]
            .iter()
            .map(|&w| {
                let gate = MemoryGate::new(None);
                let obs = CollectingObserver::new();
                Scheduler::new(w)
                    .run(&gate, obs.as_ref(), unit_jobs(8, 1), |job, sink| {
                        sink.emit(PipelineEvent::LossTick {
                            job: job.id,
                            step: 0,
                            loss: job.id as f32,
                        });
                        Ok(())
                    })
                    .unwrap();
                obs.job_sequence()
            })
            .collect();
        let want: Vec<(usize, bool)> = (0..8).flat_map(|i| [(i, false), (i, true)]).collect();
        assert_eq!(streams[0], want);
        assert_eq!(streams[1], want, "parallel delivery must match serial");
    }

    #[test]
    fn per_job_seed_mixes_id() {
        let j = CalibJob::new(5, "x", 0, ());
        assert_eq!(j.seed(0xff), 0xff ^ 5);
        assert_eq!(CalibJob::new(0, "r1", 0, ()).seed(42), 42);
    }

    #[test]
    fn gate_bounds_jobs_in_flight() {
        // Budget fits exactly one job: concurrency must collapse to 1.
        let gate = MemoryGate::new(Some(100));
        let in_flight = AtomicUsize::new(0);
        let max_seen = AtomicUsize::new(0);
        Scheduler::new(4)
            .run(&gate, &NullObserver, unit_jobs(12, 60), |_, _| {
                let now = in_flight.fetch_add(1, Ordering::SeqCst) + 1;
                max_seen.fetch_max(now, Ordering::SeqCst);
                std::thread::yield_now();
                in_flight.fetch_sub(1, Ordering::SeqCst);
                Ok(())
            })
            .unwrap();
        assert_eq!(max_seen.load(Ordering::SeqCst), 1, "gate leaked concurrency");
        assert!(gate.peak_bytes() <= 100);
    }

    #[test]
    fn oversized_job_fails_with_label() {
        let gate = MemoryGate::new(Some(100));
        let err = Scheduler::new(2)
            .run(&gate, &NullObserver, unit_jobs(3, 101), |_, _| Ok(()))
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("job 0 (j0) failed"), "got: {msg}");
        assert!(msg.contains("memory budget"), "got: {msg}");
    }

    #[test]
    fn lowest_failing_job_wins() {
        let gate = MemoryGate::new(None);
        let err = Scheduler::new(4)
            .run(&gate, &NullObserver, unit_jobs(8, 1), |job, _| {
                if job.id >= 3 {
                    anyhow::bail!("sabotaged {}", job.id);
                }
                Ok(())
            })
            .unwrap_err();
        assert!(format!("{err:#}").contains("job 3 (j3)"), "got: {err:#}");
    }
}
