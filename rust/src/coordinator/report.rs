//! Pipeline reporting surface: typed stage/progress events, the observer
//! hook every front-end (CLI, examples, benches) consumes, and the
//! JSON-serializable run report (`util::json`; serde is not vendored).

use crate::data::Dialect;
use crate::model::Weights;
use crate::rotation::RotationSet;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// The four discrete pipeline stages, in execution order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// Data-plane activation capture (strategies that calibrate on pools).
    Capture,
    /// Rotation calibration / generation.
    Calibrate,
    /// Rotation fusion + optional SmoothQuant scaling.
    Fuse,
    /// Weight quantization.
    Quantize,
}

impl Stage {
    pub const ALL: [Stage; 4] = [Stage::Capture, Stage::Calibrate, Stage::Fuse, Stage::Quantize];

    pub fn name(&self) -> &'static str {
        match self {
            Stage::Capture => "capture",
            Stage::Calibrate => "calibrate",
            Stage::Fuse => "fuse",
            Stage::Quantize => "quantize",
        }
    }
}

/// Typed progress events emitted during a pipeline run.
///
/// Stage events arrive strictly in stage order; `JobAdmitted`/`LossTick`
/// arrive between a stage's started/finished pair (gate admissions in
/// worker-completion order when calibration jobs run on the pool).
#[derive(Clone, Debug)]
pub enum PipelineEvent {
    StageStarted {
        stage: Stage,
    },
    StageFinished {
        stage: Stage,
        elapsed: Duration,
    },
    /// A calibration job was admitted by the memory gate.
    JobAdmitted {
        /// 0 = R1 (or the single end-to-end job); `l + 1` = layer `l`'s R2.
        job: usize,
        bytes: u64,
    },
    /// One optimizer step of one calibration job.
    LossTick {
        job: usize,
        step: usize,
        loss: f32,
    },
}

/// Observer hook for [`PipelineEvent`]s. Implementations must be
/// `Send + Sync`: calibration jobs emit from worker threads.
pub trait PipelineObserver: Send + Sync {
    fn on_event(&self, event: &PipelineEvent);
}

/// Discards every event (the default observer).
pub struct NullObserver;

impl PipelineObserver for NullObserver {
    fn on_event(&self, _event: &PipelineEvent) {}
}

/// Records every event for later inspection (tests, reporting).
#[derive(Default)]
pub struct CollectingObserver {
    events: Mutex<Vec<PipelineEvent>>,
}

impl CollectingObserver {
    pub fn new() -> Arc<CollectingObserver> {
        Arc::new(CollectingObserver::default())
    }

    pub fn events(&self) -> Vec<PipelineEvent> {
        self.events.lock().unwrap().clone()
    }

    /// The stage event sequence as `(stage, finished)` pairs, in arrival
    /// order (loss ticks and admissions filtered out).
    pub fn stage_sequence(&self) -> Vec<(Stage, bool)> {
        self.events()
            .iter()
            .filter_map(|e| match e {
                PipelineEvent::StageStarted { stage } => Some((*stage, false)),
                PipelineEvent::StageFinished { stage, .. } => Some((*stage, true)),
                _ => None,
            })
            .collect()
    }
}

impl PipelineObserver for CollectingObserver {
    fn on_event(&self, event: &PipelineEvent) {
        self.events.lock().unwrap().push(event.clone());
    }
}

/// Prints one line per finished stage — the CLI's progress surface.
pub struct PrintObserver;

impl PipelineObserver for PrintObserver {
    fn on_event(&self, event: &PipelineEvent) {
        if let PipelineEvent::StageFinished { stage, elapsed } = event {
            println!("  stage {:9} {}", stage.name(), crate::util::fmt_duration(*elapsed));
        }
    }
}

/// Timing + memory accounting of one pipeline run (Table 3 / Fig 1 data).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PipelineStats {
    pub capture_time: Duration,
    pub calibrate_time: Duration,
    pub fuse_time: Duration,
    pub quantize_time: Duration,
    pub total_time: Duration,
    /// Peak job-resident bytes admitted by the memory gate.
    pub peak_job_bytes: u64,
    /// Calibration loss curves (R1 first, then R2 per layer).
    pub loss_curves: Vec<Vec<f32>>,
}

fn dur_json(d: Duration) -> Json {
    // Integer nanoseconds: exact round-trip for any run under ~104 days.
    Json::Num(d.as_nanos() as f64)
}

fn json_dur(j: &Json, key: &str) -> Result<Duration> {
    let ns = j.get_f64(key).with_context(|| format!("stats field {key:?} missing"))?;
    Ok(Duration::from_nanos(ns as u64))
}

impl PipelineStats {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("capture_ns", dur_json(self.capture_time)),
            ("calibrate_ns", dur_json(self.calibrate_time)),
            ("fuse_ns", dur_json(self.fuse_time)),
            ("quantize_ns", dur_json(self.quantize_time)),
            ("total_ns", dur_json(self.total_time)),
            ("peak_job_bytes", Json::Num(self.peak_job_bytes as f64)),
            (
                "loss_curves",
                Json::Arr(
                    self.loss_curves
                        .iter()
                        .map(|c| Json::Arr(c.iter().map(|&l| Json::Num(l as f64)).collect()))
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<PipelineStats> {
        let curves = j
            .get("loss_curves")
            .and_then(|v| v.as_arr())
            .context("stats field \"loss_curves\" missing")?
            .iter()
            .map(|c| {
                c.as_arr()
                    .context("loss curve must be an array")
                    .map(|xs| xs.iter().filter_map(|x| x.as_f64()).map(|x| x as f32).collect())
            })
            .collect::<Result<Vec<Vec<f32>>>>()?;
        Ok(PipelineStats {
            capture_time: json_dur(j, "capture_ns")?,
            calibrate_time: json_dur(j, "calibrate_ns")?,
            fuse_time: json_dur(j, "fuse_ns")?,
            quantize_time: json_dur(j, "quantize_ns")?,
            total_time: json_dur(j, "total_ns")?,
            peak_job_bytes: j.get_f64("peak_job_bytes").context("peak_job_bytes missing")? as u64,
            loss_curves: curves,
        })
    }
}

/// Pipeline output: quantized (dequantized-f32) weights ready for the
/// `fwdq_*` artifacts, plus the rotation set actually applied and the run
/// accounting. `record()` strips the weights for machine-readable output.
pub struct PipelineReport {
    pub weights: Weights,
    pub rotation: Option<RotationSet>,
    pub stats: PipelineStats,
    /// Registry name of the method / rotation strategy that ran.
    pub method: String,
    /// Name of the weight quantizer that ran ("none" at W16).
    pub quantizer: String,
    /// Calibration dialect the run used.
    pub dialect: Dialect,
}

impl PipelineReport {
    pub fn record(&self) -> PipelineRecord {
        PipelineRecord {
            method: self.method.clone(),
            quantizer: self.quantizer.clone(),
            dialect: self.dialect,
            rotated: self.rotation.is_some(),
            online_had: self.rotation.as_ref().map(|r| r.online_had).unwrap_or(false),
            stats: self.stats.clone(),
        }
    }

    /// Machine-readable row (everything except the weights themselves).
    pub fn to_json(&self) -> Json {
        self.record().to_json()
    }
}

/// The serializable summary of one pipeline run.
#[derive(Clone, Debug, PartialEq)]
pub struct PipelineRecord {
    pub method: String,
    pub quantizer: String,
    pub dialect: Dialect,
    pub rotated: bool,
    pub online_had: bool,
    pub stats: PipelineStats,
}

impl PipelineRecord {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("method", Json::Str(self.method.clone())),
            ("quantizer", Json::Str(self.quantizer.clone())),
            ("dialect", Json::Str(self.dialect.label().to_string())),
            ("rotated", Json::Bool(self.rotated)),
            ("online_had", Json::Bool(self.online_had)),
            ("stats", self.stats.to_json()),
        ])
    }

    pub fn from_json(j: &Json) -> Result<PipelineRecord> {
        Ok(PipelineRecord {
            method: j.get_str("method").context("record field \"method\" missing")?.to_string(),
            quantizer: j
                .get_str("quantizer")
                .context("record field \"quantizer\" missing")?
                .to_string(),
            dialect: Dialect::parse(j.get_str("dialect").context("record field \"dialect\" missing")?)?,
            rotated: j.get("rotated").and_then(|v| v.as_bool()).unwrap_or(false),
            online_had: j.get("online_had").and_then(|v| v.as_bool()).unwrap_or(false),
            stats: PipelineStats::from_json(j.get("stats").context("record field \"stats\" missing")?)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_json_roundtrip_is_exact() {
        let stats = PipelineStats {
            capture_time: Duration::from_micros(1234),
            calibrate_time: Duration::from_millis(56),
            fuse_time: Duration::from_nanos(789),
            quantize_time: Duration::from_secs(1),
            total_time: Duration::from_millis(1100),
            peak_job_bytes: 24 << 20,
            loss_curves: vec![vec![1.5, 0.75, 0.5], vec![2.0]],
        };
        let j = stats.to_json().to_string();
        let back = PipelineStats::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(back, stats);
    }

    #[test]
    fn record_json_roundtrip() {
        let rec = PipelineRecord {
            method: "DartQuant".into(),
            quantizer: "gptq".into(),
            dialect: Dialect::Ptb,
            rotated: true,
            online_had: true,
            stats: PipelineStats { peak_job_bytes: 42, ..Default::default() },
        };
        let j = rec.to_json().to_string();
        let back = PipelineRecord::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(back, rec);
    }

    #[test]
    fn stage_order_and_names() {
        assert_eq!(Stage::ALL[0].name(), "capture");
        assert_eq!(Stage::ALL[3].name(), "quantize");
    }

    #[test]
    fn collecting_observer_preserves_order() {
        let obs = CollectingObserver::new();
        obs.on_event(&PipelineEvent::StageStarted { stage: Stage::Capture });
        obs.on_event(&PipelineEvent::LossTick { job: 0, step: 0, loss: 1.0 });
        obs.on_event(&PipelineEvent::StageFinished {
            stage: Stage::Capture,
            elapsed: Duration::ZERO,
        });
        assert_eq!(obs.stage_sequence(), vec![(Stage::Capture, false), (Stage::Capture, true)]);
        assert_eq!(obs.events().len(), 3);
    }
}
