//! Pipeline reporting surface: typed stage/progress events, the observer
//! hook every front-end (CLI, examples, benches) consumes, and the
//! JSON-serializable run report (`util::json`; serde is not vendored).

use crate::data::Dialect;
use crate::model::Weights;
use crate::rotation::RotationSet;
use crate::util::json::Json;
use crate::util::sync::lock_or_poisoned;
use anyhow::{Context, Result};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// The four discrete pipeline stages, in execution order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// Data-plane activation capture (strategies that calibrate on pools).
    Capture,
    /// Rotation calibration / generation.
    Calibrate,
    /// Rotation fusion + optional SmoothQuant scaling.
    Fuse,
    /// Weight quantization.
    Quantize,
}

impl Stage {
    /// All four stages in execution order.
    pub const ALL: [Stage; 4] = [Stage::Capture, Stage::Calibrate, Stage::Fuse, Stage::Quantize];

    /// Lowercase stage name as printed by the CLI and benches.
    pub fn name(&self) -> &'static str {
        match self {
            Stage::Capture => "capture",
            Stage::Calibrate => "calibrate",
            Stage::Fuse => "fuse",
            Stage::Quantize => "quantize",
        }
    }
}

/// Typed progress events emitted during a pipeline run.
///
/// Stage events arrive strictly in stage order. Job events
/// (`JobStarted`/`JobAdmitted`/`LossTick`/`JobFinished`) arrive between
/// their stage's started/finished pair; when jobs run on the parallel
/// scheduler ([`super::Scheduler`]) they are buffered per job and
/// delivered in **job-id order** after the join, so the stream is
/// identical at any worker count (the ordered-delivery half of the
/// determinism contract — see `docs/CONCURRENCY.md`).
#[derive(Clone, Debug)]
pub enum PipelineEvent {
    /// A pipeline stage began.
    StageStarted {
        /// Which stage.
        stage: Stage,
    },
    /// A pipeline stage completed.
    StageFinished {
        /// Which stage.
        stage: Stage,
        /// Stage wall-clock time.
        elapsed: Duration,
    },
    /// A scheduler job began (before memory-gate admission).
    JobStarted {
        /// Job id: 0 = R1 (or the single end-to-end job); `l + 1` =
        /// layer `l`'s R2 job. Quantizer jobs number their own space.
        job: usize,
        /// The job's human-readable label (`"r1"`, `"r2[3]"`, …).
        label: String,
    },
    /// A calibration job was admitted by the memory gate.
    JobAdmitted {
        /// 0 = R1 (or the single end-to-end job); `l + 1` = layer `l`'s R2.
        job: usize,
        /// The bytes the gate charged for this job.
        bytes: u64,
    },
    /// One optimizer step of one calibration job.
    LossTick {
        /// The job the step belongs to.
        job: usize,
        /// Step index within the job's optimization loop.
        step: usize,
        /// The objective value after this step.
        loss: f32,
    },
    /// A scheduler job finished (successfully or not).
    JobFinished {
        /// The job that finished.
        job: usize,
        /// Wall clock from `JobStarted`, gate wait included.
        elapsed: Duration,
        /// Whether the job returned `Ok`.
        ok: bool,
    },
}

/// Observer hook for [`PipelineEvent`]s. Implementations must be
/// `Send + Sync`: calibration jobs emit from worker threads.
pub trait PipelineObserver: Send + Sync {
    /// Receive one event. Called synchronously from the pipeline thread
    /// (scheduler-job events are buffered and replayed there too), so
    /// implementations should return quickly.
    fn on_event(&self, event: &PipelineEvent);
}

/// Discards every event (the default observer).
pub struct NullObserver;

impl PipelineObserver for NullObserver {
    fn on_event(&self, _event: &PipelineEvent) {}
}

/// Records every event for later inspection (tests, reporting).
#[derive(Default)]
pub struct CollectingObserver {
    events: Mutex<Vec<PipelineEvent>>,
}

impl CollectingObserver {
    /// A fresh observer behind the `Arc` the builder wants.
    pub fn new() -> Arc<CollectingObserver> {
        Arc::new(CollectingObserver::default())
    }

    /// Snapshot of every event received so far, in arrival order.
    pub fn events(&self) -> Vec<PipelineEvent> {
        lock_or_poisoned(&self.events).clone()
    }

    /// The stage event sequence as `(stage, finished)` pairs, in arrival
    /// order (loss ticks and job events filtered out).
    pub fn stage_sequence(&self) -> Vec<(Stage, bool)> {
        self.events()
            .iter()
            .filter_map(|e| match e {
                PipelineEvent::StageStarted { stage } => Some((*stage, false)),
                PipelineEvent::StageFinished { stage, .. } => Some((*stage, true)),
                _ => None,
            })
            .collect()
    }

    /// The scheduler-job event sequence as `(job, finished)` pairs, in
    /// arrival order (`JobStarted` → `(id, false)`, `JobFinished` →
    /// `(id, true)`; admissions and loss ticks filtered out). Under the
    /// ordered-delivery contract this sequence is identical at any
    /// worker count.
    pub fn job_sequence(&self) -> Vec<(usize, bool)> {
        self.events()
            .iter()
            .filter_map(|e| match e {
                PipelineEvent::JobStarted { job, .. } => Some((*job, false)),
                PipelineEvent::JobFinished { job, .. } => Some((*job, true)),
                _ => None,
            })
            .collect()
    }
}

impl PipelineObserver for CollectingObserver {
    fn on_event(&self, event: &PipelineEvent) {
        lock_or_poisoned(&self.events).push(event.clone());
    }
}

/// Prints one line per finished stage — the CLI's progress surface.
pub struct PrintObserver;

impl PipelineObserver for PrintObserver {
    fn on_event(&self, event: &PipelineEvent) {
        if let PipelineEvent::StageFinished { stage, elapsed } = event {
            println!("  stage {:9} {}", stage.name(), crate::util::fmt_duration(*elapsed));
        }
    }
}

/// Timing + memory accounting of one pipeline run (Table 3 / Fig 1 data).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PipelineStats {
    /// Wall clock of the capture stage.
    pub capture_time: Duration,
    /// Wall clock of the calibrate stage (all scheduler jobs joined).
    pub calibrate_time: Duration,
    /// Wall clock of the fuse/smooth stage.
    pub fuse_time: Duration,
    /// Wall clock of the weight-quantization stage.
    pub quantize_time: Duration,
    /// Wall clock of the whole pipeline.
    pub total_time: Duration,
    /// Peak job-resident bytes admitted by the memory gate.
    pub peak_job_bytes: u64,
    /// Peak resident weight bytes checked out of the `WeightStore` —
    /// nonzero only for streamed (out-of-core) runs, where it is bounded
    /// by the configured resident budget (see `docs/STREAMING.md`).
    pub peak_weight_bytes: u64,
    /// Calibration loss curves (R1 first, then R2 per layer).
    pub loss_curves: Vec<Vec<f32>>,
}

fn dur_json(d: Duration) -> Json {
    // Integer nanoseconds: exact round-trip for any run under ~104 days.
    Json::Num(d.as_nanos() as f64)
}

fn json_dur(j: &Json, key: &str) -> Result<Duration> {
    let ns = j.get_f64(key).with_context(|| format!("stats field {key:?} missing"))?;
    Ok(Duration::from_nanos(ns as u64))
}

impl PipelineStats {
    /// The run-invariant subset of the stats: wall-clock timings and the
    /// scheduling-dependent `peak_job_bytes` / `peak_weight_bytes`
    /// zeroed; the deterministic fields (loss curves) kept. Under the
    /// scheduler's determinism contract two runs of the same
    /// configuration serialize identically here at **any** worker count
    /// — and, per `docs/STREAMING.md`, at any `--streaming` /
    /// `--resident-budget` setting — the byte-identity the scheduler and
    /// streaming tests and `pipeline --json --canonical` rely on.
    pub fn canonical(&self) -> PipelineStats {
        PipelineStats {
            capture_time: Duration::ZERO,
            calibrate_time: Duration::ZERO,
            fuse_time: Duration::ZERO,
            quantize_time: Duration::ZERO,
            total_time: Duration::ZERO,
            peak_job_bytes: 0,
            peak_weight_bytes: 0,
            loss_curves: self.loss_curves.clone(),
        }
    }

    /// Serialize to the `util::json` tree (nanosecond-integer durations).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("capture_ns", dur_json(self.capture_time)),
            ("calibrate_ns", dur_json(self.calibrate_time)),
            ("fuse_ns", dur_json(self.fuse_time)),
            ("quantize_ns", dur_json(self.quantize_time)),
            ("total_ns", dur_json(self.total_time)),
            ("peak_job_bytes", Json::Num(self.peak_job_bytes as f64)),
            ("peak_weight_bytes", Json::Num(self.peak_weight_bytes as f64)),
            (
                "loss_curves",
                Json::Arr(
                    self.loss_curves
                        .iter()
                        .map(|c| Json::Arr(c.iter().map(|&l| Json::Num(l as f64)).collect()))
                        .collect(),
                ),
            ),
        ])
    }

    /// Parse the [`PipelineStats::to_json`] representation back.
    pub fn from_json(j: &Json) -> Result<PipelineStats> {
        let curves = j
            .get("loss_curves")
            .and_then(|v| v.as_arr())
            .context("stats field \"loss_curves\" missing")?
            .iter()
            .map(|c| {
                c.as_arr()
                    .context("loss curve must be an array")
                    .map(|xs| xs.iter().filter_map(|x| x.as_f64()).map(|x| x as f32).collect())
            })
            .collect::<Result<Vec<Vec<f32>>>>()?;
        Ok(PipelineStats {
            capture_time: json_dur(j, "capture_ns")?,
            calibrate_time: json_dur(j, "calibrate_ns")?,
            fuse_time: json_dur(j, "fuse_ns")?,
            quantize_time: json_dur(j, "quantize_ns")?,
            total_time: json_dur(j, "total_ns")?,
            peak_job_bytes: j.get_f64("peak_job_bytes").context("peak_job_bytes missing")? as u64,
            // Absent in pre-streaming reports — default to 0 so old rows
            // still parse.
            peak_weight_bytes: j.get_f64("peak_weight_bytes").unwrap_or(0.0) as u64,
            loss_curves: curves,
        })
    }
}

/// Pipeline output: quantized weights (packed `QMat` linears under
/// `--packed`, dequantized f32 otherwise), plus the rotation set actually
/// applied and the run accounting. `record()` strips the weights for
/// machine-readable output.
pub struct PipelineReport {
    /// The quantized model.
    pub weights: Weights,
    /// The rotation set that was fused into the weights, if the method
    /// rotates.
    pub rotation: Option<RotationSet>,
    /// Timing / memory / loss accounting for the run.
    pub stats: PipelineStats,
    /// Registry name of the method / rotation strategy that ran.
    pub method: String,
    /// Name of the weight quantizer that ran ("none" at W16).
    pub quantizer: String,
    /// Calibration dialect the run used.
    pub dialect: Dialect,
    /// True resident weight bytes of the output model (packed
    /// codes + scales for packed tensors, dense f32 otherwise).
    pub model_bytes: u64,
    /// Dense-f32-equivalent bytes of the transformer linears.
    pub linear_dense_bytes: u64,
    /// Actual stored bytes of the transformer linears.
    pub linear_actual_bytes: u64,
}

impl PipelineReport {
    /// Dense-f32 bytes ÷ actual bytes over the transformer linears (the
    /// quantized weight residency; 1.0 for dense fake-quant output).
    pub fn compression_ratio(&self) -> f64 {
        ratio(self.linear_dense_bytes, self.linear_actual_bytes)
    }

    /// The serializable summary row (everything except the weights).
    pub fn record(&self) -> PipelineRecord {
        PipelineRecord {
            method: self.method.clone(),
            quantizer: self.quantizer.clone(),
            dialect: self.dialect,
            rotated: self.rotation.is_some(),
            online_had: self.rotation.as_ref().map(|r| r.online_had).unwrap_or(false),
            model_bytes: self.model_bytes,
            linear_dense_bytes: self.linear_dense_bytes,
            linear_actual_bytes: self.linear_actual_bytes,
            stats: self.stats.clone(),
        }
    }

    /// Machine-readable row (everything except the weights themselves).
    pub fn to_json(&self) -> Json {
        self.record().to_json()
    }
}

fn ratio(dense: u64, actual: u64) -> f64 {
    if actual == 0 {
        1.0
    } else {
        dense as f64 / actual as f64
    }
}

/// The serializable summary of one pipeline run.
#[derive(Clone, Debug, PartialEq)]
pub struct PipelineRecord {
    /// Registry name of the method that ran.
    pub method: String,
    /// Name of the weight quantizer that ran.
    pub quantizer: String,
    /// Calibration dialect.
    pub dialect: Dialect,
    /// Whether a rotation set was produced and fused.
    pub rotated: bool,
    /// Whether the rotation set enables the online R3/R4 Hadamards.
    pub online_had: bool,
    /// True resident weight bytes of the output model.
    pub model_bytes: u64,
    /// Dense-f32-equivalent bytes of the transformer linears.
    pub linear_dense_bytes: u64,
    /// Actual stored bytes of the transformer linears.
    pub linear_actual_bytes: u64,
    /// The run's accounting (see [`PipelineStats`]).
    pub stats: PipelineStats,
}

impl PipelineRecord {
    /// Dense-f32 bytes ÷ actual bytes over the transformer linears.
    pub fn compression_ratio(&self) -> f64 {
        ratio(self.linear_dense_bytes, self.linear_actual_bytes)
    }

    /// The record with [`PipelineStats::canonical`] applied: strips every
    /// run-varying field so that two runs of the same configuration — at
    /// any `workers` setting — serialize byte-identically. (The byte
    /// accounting is deterministic, so it survives canonicalization.)
    pub fn canonical(&self) -> PipelineRecord {
        PipelineRecord { stats: self.stats.canonical(), ..self.clone() }
    }

    /// Serialize to the `util::json` tree. `compression_ratio` is a
    /// derived convenience field; the integer byte counts are
    /// authoritative (and exactly round-trippable).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("method", Json::Str(self.method.clone())),
            ("quantizer", Json::Str(self.quantizer.clone())),
            ("dialect", Json::Str(self.dialect.label().to_string())),
            ("rotated", Json::Bool(self.rotated)),
            ("online_had", Json::Bool(self.online_had)),
            ("model_bytes", Json::Num(self.model_bytes as f64)),
            ("linear_dense_bytes", Json::Num(self.linear_dense_bytes as f64)),
            ("linear_actual_bytes", Json::Num(self.linear_actual_bytes as f64)),
            ("compression_ratio", Json::Num(self.compression_ratio())),
            ("stats", self.stats.to_json()),
        ])
    }

    /// Parse the [`PipelineRecord::to_json`] representation back.
    pub fn from_json(j: &Json) -> Result<PipelineRecord> {
        Ok(PipelineRecord {
            method: j.get_str("method").context("record field \"method\" missing")?.to_string(),
            quantizer: j
                .get_str("quantizer")
                .context("record field \"quantizer\" missing")?
                .to_string(),
            dialect: Dialect::parse(j.get_str("dialect").context("record field \"dialect\" missing")?)?,
            rotated: j.get("rotated").and_then(|v| v.as_bool()).unwrap_or(false),
            online_had: j.get("online_had").and_then(|v| v.as_bool()).unwrap_or(false),
            model_bytes: j.get_f64("model_bytes").unwrap_or(0.0) as u64,
            linear_dense_bytes: j.get_f64("linear_dense_bytes").unwrap_or(0.0) as u64,
            linear_actual_bytes: j.get_f64("linear_actual_bytes").unwrap_or(0.0) as u64,
            stats: PipelineStats::from_json(j.get("stats").context("record field \"stats\" missing")?)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_json_roundtrip_is_exact() {
        let stats = PipelineStats {
            capture_time: Duration::from_micros(1234),
            calibrate_time: Duration::from_millis(56),
            fuse_time: Duration::from_nanos(789),
            quantize_time: Duration::from_secs(1),
            total_time: Duration::from_millis(1100),
            peak_job_bytes: 24 << 20,
            peak_weight_bytes: 3 << 20,
            loss_curves: vec![vec![1.5, 0.75, 0.5], vec![2.0]],
        };
        let j = stats.to_json().to_string();
        let back = PipelineStats::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(back, stats);
    }

    #[test]
    fn record_json_roundtrip() {
        let rec = PipelineRecord {
            method: "DartQuant".into(),
            quantizer: "gptq".into(),
            dialect: Dialect::Ptb,
            rotated: true,
            online_had: true,
            model_bytes: 123_456,
            linear_dense_bytes: 800_000,
            linear_actual_bytes: 100_000,
            stats: PipelineStats { peak_job_bytes: 42, ..Default::default() },
        };
        let j = rec.to_json().to_string();
        let back = PipelineRecord::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(back, rec);
        assert_eq!(back.compression_ratio(), 8.0);
    }

    #[test]
    fn canonical_strips_run_varying_fields_only() {
        let rec = PipelineRecord {
            method: "DartQuant".into(),
            quantizer: "rtn".into(),
            dialect: Dialect::Wiki,
            rotated: true,
            online_had: true,
            model_bytes: 4096,
            linear_dense_bytes: 2048,
            linear_actual_bytes: 512,
            stats: PipelineStats {
                capture_time: Duration::from_millis(3),
                calibrate_time: Duration::from_millis(14),
                fuse_time: Duration::from_millis(1),
                quantize_time: Duration::from_millis(5),
                total_time: Duration::from_millis(23),
                peak_job_bytes: 999,
                peak_weight_bytes: 555,
                loss_curves: vec![vec![2.0, 1.0]],
            },
        };
        let canon = rec.canonical();
        assert_eq!(canon.stats.total_time, Duration::ZERO);
        assert_eq!(canon.stats.peak_job_bytes, 0);
        assert_eq!(canon.stats.peak_weight_bytes, 0, "streamed peak is run-varying");
        assert_eq!(canon.stats.loss_curves, rec.stats.loss_curves);
        assert_eq!(canon.method, rec.method);
        // The deterministic byte accounting survives canonicalization.
        assert_eq!(canon.model_bytes, rec.model_bytes);
        assert_eq!(canon.compression_ratio(), 4.0);
        // Canonicalizing twice is a fixpoint and serializes identically.
        assert_eq!(canon.canonical().to_json().to_string(), canon.to_json().to_string());
    }

    #[test]
    fn job_sequence_filters_job_events() {
        let obs = CollectingObserver::new();
        obs.on_event(&PipelineEvent::JobStarted { job: 0, label: "r1".into() });
        obs.on_event(&PipelineEvent::JobAdmitted { job: 0, bytes: 10 });
        obs.on_event(&PipelineEvent::LossTick { job: 0, step: 0, loss: 1.0 });
        obs.on_event(&PipelineEvent::JobFinished {
            job: 0,
            elapsed: Duration::ZERO,
            ok: true,
        });
        obs.on_event(&PipelineEvent::StageFinished {
            stage: Stage::Calibrate,
            elapsed: Duration::ZERO,
        });
        assert_eq!(obs.job_sequence(), vec![(0, false), (0, true)]);
    }

    #[test]
    fn stage_order_and_names() {
        assert_eq!(Stage::ALL[0].name(), "capture");
        assert_eq!(Stage::ALL[3].name(), "quantize");
    }

    #[test]
    fn collecting_observer_preserves_order() {
        let obs = CollectingObserver::new();
        obs.on_event(&PipelineEvent::StageStarted { stage: Stage::Capture });
        obs.on_event(&PipelineEvent::LossTick { job: 0, step: 0, loss: 1.0 });
        obs.on_event(&PipelineEvent::StageFinished {
            stage: Stage::Capture,
            elapsed: Duration::ZERO,
        });
        assert_eq!(obs.stage_sequence(), vec![(Stage::Capture, false), (Stage::Capture, true)]);
        assert_eq!(obs.events().len(), 3);
    }
}
