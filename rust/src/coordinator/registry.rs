//! The open method space: `RotationStrategy` × `WeightQuantizer` traits,
//! the built-in implementations (the rows of Table 2), and the
//! `MethodRegistry` mapping names/aliases → composed method specs.
//!
//! DartQuant's own contribution (whip + QR-Orth calibration) is just one
//! `RotationStrategy`; new baselines (DFRot-style refined rotations,
//! ConQuR-style corner objectives) plug in by registering a spec — the
//! coordinator's hot path never changes.

use super::budget::MemoryGate;
use super::capture::{self, CalibrationPools};
use super::report::{PipelineEvent, PipelineObserver};
use super::{job_bytes, spin_job_bytes, PipelineConfig};
use crate::calib::{self, CalibConfig};
use crate::data::Corpus;
use crate::model::{TokenBatch, Weights};
use crate::quant::{self, GptqConfig};
use crate::rotation::RotationSet;
use crate::runtime::{with_thread_runtime, Runtime};
use crate::util::prng::Pcg64;
use crate::util::threadpool::ThreadPool;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::sync::Arc;

// ---------------------------------------------------------------------------
// Stage context — what every strategy/quantizer sees.
// ---------------------------------------------------------------------------

/// Everything a pipeline stage may need. Strategies are stateless trait
/// objects; all run-specific knobs come through here.
pub struct StageContext<'a> {
    /// PJRT runtime; `None` for native-only runs. Strategies that need
    /// AOT artifacts call [`StageContext::runtime`] and surface a
    /// contextful error when absent.
    pub rt: Option<&'a Runtime>,
    pub cfg: &'a PipelineConfig,
    pub weights: &'a Weights,
    pub corpus: &'a Corpus,
    pub gate: Arc<MemoryGate>,
    pub observer: Arc<dyn PipelineObserver>,
}

impl StageContext<'_> {
    pub fn runtime(&self) -> Result<&Runtime> {
        self.rt.context(
            "this stage needs the PJRT runtime (run `make artifacts`, then use Pipeline::run)",
        )
    }

    pub fn emit(&self, event: PipelineEvent) {
        self.observer.on_event(&event);
    }
}

// ---------------------------------------------------------------------------
// Trait families.
// ---------------------------------------------------------------------------

/// What a rotation-calibration stage produced.
pub struct RotationOutcome {
    pub rotation: Option<RotationSet>,
    /// Loss trajectories (R1 first, then R2 per layer) for methods that
    /// optimize; empty for closed-form strategies.
    pub loss_curves: Vec<Vec<f32>>,
}

impl RotationOutcome {
    pub fn none() -> RotationOutcome {
        RotationOutcome { rotation: None, loss_curves: Vec::new() }
    }

    pub fn some(rotation: RotationSet) -> RotationOutcome {
        RotationOutcome { rotation: Some(rotation), loss_curves: Vec::new() }
    }
}

/// How the rotation set is produced — the open axis of the method space.
/// Out-of-tree strategies implement this and register a [`MethodSpec`];
/// the coordinator never needs editing.
pub trait RotationStrategy: Send + Sync {
    fn name(&self) -> &str;

    /// Capture-stage work (activation pools for pool-based calibration).
    /// Default: nothing to capture.
    fn capture(&self, _ctx: &StageContext) -> Result<Option<CalibrationPools>> {
        Ok(None)
    }

    /// Calibrate-stage work: produce the rotation set (`None` rotation =
    /// the method does not rotate).
    fn calibrate(
        &self,
        ctx: &StageContext,
        pools: Option<&CalibrationPools>,
    ) -> Result<RotationOutcome>;
}

/// How weights are quantized after rotation fusion.
pub trait WeightQuantizer: Send + Sync {
    fn name(&self) -> &str;

    fn quantize(&self, ctx: &StageContext, weights: &Weights) -> Result<Weights>;
}

// ---------------------------------------------------------------------------
// Built-in rotation strategies.
// ---------------------------------------------------------------------------

/// No rotation (RTN / SmoothQuant / GPTQ / OmniQuant baselines).
pub struct NoRotation;

impl RotationStrategy for NoRotation {
    fn name(&self) -> &str {
        "none"
    }

    fn calibrate(
        &self,
        _ctx: &StageContext,
        _pools: Option<&CalibrationPools>,
    ) -> Result<RotationOutcome> {
        Ok(RotationOutcome::none())
    }
}

/// Random-Hadamard R1/R2 (+ online R3/R4) — QuaRot.
pub struct RandomHadamard;

impl RotationStrategy for RandomHadamard {
    fn name(&self) -> &str {
        "random-hadamard"
    }

    fn calibrate(
        &self,
        ctx: &StageContext,
        _pools: Option<&CalibrationPools>,
    ) -> Result<RotationOutcome> {
        let cfg = &ctx.weights.cfg;
        let mut rng = Pcg64::new(ctx.cfg.seed ^ 0x707);
        Ok(RotationOutcome::some(RotationSet::random_hadamard(
            cfg.dim,
            cfg.head_dim,
            cfg.n_layers,
            &mut rng,
        )))
    }
}

/// Haar-random orthogonal rotations — the ablation QuaRot found inferior
/// to Hadamard (kept as a registered strategy for the method grid).
pub struct RandomOrthogonal;

impl RotationStrategy for RandomOrthogonal {
    fn name(&self) -> &str {
        "random-orthogonal"
    }

    fn calibrate(
        &self,
        ctx: &StageContext,
        _pools: Option<&CalibrationPools>,
    ) -> Result<RotationOutcome> {
        let cfg = &ctx.weights.cfg;
        let mut rng = Pcg64::new(ctx.cfg.seed ^ 0x707);
        Ok(RotationOutcome::some(RotationSet::random_orthogonal(
            cfg.dim,
            cfg.head_dim,
            cfg.n_layers,
            &mut rng,
        )))
    }
}

/// End-to-end Cayley fine-tuning of R1 (SpinQuant-sim; + smooth scales =
/// OSTQuant-sim). ONE job holding the whole model + optimizer + backprop
/// state; charged in full against the memory gate — Table 3's resource
/// story.
pub struct SpinCayley;

impl RotationStrategy for SpinCayley {
    fn name(&self) -> &str {
        "spin-cayley"
    }

    fn calibrate(
        &self,
        ctx: &StageContext,
        _pools: Option<&CalibrationPools>,
    ) -> Result<RotationOutcome> {
        let rt = ctx.runtime()?;
        let model_cfg = ctx.weights.cfg.clone();
        let need = spin_job_bytes(&model_cfg);
        let _lease = ctx.gate.admit(need).map_err(|e| {
            anyhow::anyhow!("{} cannot run under this memory budget: {e}", self.name())
        })?;
        ctx.emit(PipelineEvent::JobAdmitted { job: 0, bytes: need });
        let dialect = ctx.cfg.calib_dialect;
        let (vocab, seq_len) = (model_cfg.vocab, ctx.cfg.calib_seq_len);
        let res = calib::spin_calibrate(rt, ctx.weights, &ctx.cfg.spin, move |step| {
            let c = Corpus::new(dialect, vocab, 7);
            TokenBatch::new(&c.calib_sequences_at(8, seq_len, step as u64))
        })?;
        for (step, &loss) in res.losses.iter().enumerate() {
            ctx.emit(PipelineEvent::LossTick { job: 0, step, loss });
        }
        let mut rng = Pcg64::new(ctx.cfg.seed ^ 0x707);
        let rotation = RotationSet {
            r1: res.r1,
            r2: (0..model_cfg.n_layers)
                .map(|_| crate::linalg::randomized_hadamard(model_cfg.head_dim, &mut rng))
                .collect(),
            online_had: true,
        };
        Ok(RotationOutcome { rotation: Some(rotation), loss_curves: vec![res.losses] })
    }
}

/// Whip + QR-Orth rotational distribution calibration — the paper.
/// Capture (data-plane) then R1 + per-layer R2 jobs on the worker pool,
/// each admitted individually by the memory gate.
pub struct DartCalibrated;

impl RotationStrategy for DartCalibrated {
    fn name(&self) -> &str {
        "dart-calibrated"
    }

    fn capture(&self, ctx: &StageContext) -> Result<Option<CalibrationPools>> {
        let calib_seqs =
            ctx.corpus.calib_sequences(ctx.cfg.calib_sequences, ctx.cfg.calib_seq_len);
        let pools = match ctx.rt {
            Some(rt) => {
                capture::capture_pools(rt, ctx.weights, &calib_seqs, ctx.cfg.token_frac, ctx.cfg.seed)?
            }
            None => capture::capture_pools_native(
                ctx.weights,
                &calib_seqs,
                ctx.cfg.token_frac,
                ctx.cfg.seed,
            ),
        };
        Ok(Some(pools))
    }

    fn calibrate(
        &self,
        ctx: &StageContext,
        pools: Option<&CalibrationPools>,
    ) -> Result<RotationOutcome> {
        let pools = pools.context("DartCalibrated needs the capture stage's activation pools")?;
        // Jobs execute AOT artifacts on per-worker runtimes; gate on the
        // session runtime up front so `run_native()` fails with the
        // contextful error instead of a raw artifact-open failure from a
        // worker thread.
        ctx.runtime()?;
        let model_cfg = ctx.weights.cfg.clone();
        let dir = ctx.cfg.artifacts_dir.clone();
        let pool = ThreadPool::new(ctx.cfg.workers);
        let mut jobs: Vec<(usize, crate::tensor::Mat, CalibConfig)> = Vec::new();
        jobs.push((0, pools.r1_pool.clone(), ctx.cfg.calib.clone()));
        for (l, p) in pools.r2_pools.iter().enumerate() {
            let mut c2 = ctx.cfg.calib.clone();
            c2.lr = 1e-3; // paper Table 23: R2 uses lr 1e-3
            // R2 jobs always use whip (the ablation objectives are emitted
            // only at the R1 dims; matches the paper, which ablates the R1
            // objective only).
            c2.objective = crate::calib::Objective::Whip;
            jobs.push((l + 1, p.clone(), c2));
        }
        let gate = Arc::clone(&ctx.gate);
        let observer = Arc::clone(&ctx.observer);
        let results = pool.map(jobs, move |(id, pool_mat, ccfg)| -> Result<_> {
            let need = job_bytes(&pool_mat);
            let _lease = gate.admit(need)?;
            observer.on_event(&PipelineEvent::JobAdmitted { job: id, bytes: need });
            let r = with_thread_runtime(&dir, |rt| {
                calib::calibrate_rotation(rt, &pool_mat, &ccfg)
            })??;
            Ok((id, r))
        });
        let mut loss_curves = Vec::new();
        let mut r1 = None;
        let mut r2: Vec<Option<crate::tensor::Mat>> = vec![None; model_cfg.n_layers];
        for res in results {
            let (id, r) = res.context("calibration job failed")?;
            for (step, &loss) in r.losses.iter().enumerate() {
                ctx.emit(PipelineEvent::LossTick { job: id, step, loss });
            }
            loss_curves.push(r.losses.clone());
            if id == 0 {
                r1 = Some(r.rotation);
            } else {
                r2[id - 1] = Some(r.rotation);
            }
        }
        let r2 = r2
            .into_iter()
            .enumerate()
            .map(|(l, r)| {
                r.with_context(|| {
                    format!(
                        "no calibrated R2 for layer {l} ({} layers expected) — \
                         the worker pool returned no result for this job",
                        model_cfg.n_layers
                    )
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let rotation =
            RotationSet { r1: r1.context("no calibrated R1")?, r2, online_had: true };
        Ok(RotationOutcome { rotation: Some(rotation), loss_curves })
    }
}

// ---------------------------------------------------------------------------
// Built-in weight quantizers.
// ---------------------------------------------------------------------------

/// Per-output-channel symmetric RTN — the paper's weight quantizer.
pub struct RtnQuantizer;

impl WeightQuantizer for RtnQuantizer {
    fn name(&self) -> &str {
        "rtn"
    }

    fn quantize(&self, ctx: &StageContext, weights: &Weights) -> Result<Weights> {
        Ok(quant::rtn_quantize_model(weights, ctx.cfg.bits.w))
    }
}

/// GPTQ with Hessian capture over calibration sequences.
pub struct GptqQuantizer {
    pub damp: f32,
}

impl Default for GptqQuantizer {
    fn default() -> Self {
        GptqQuantizer { damp: 0.01 }
    }
}

impl WeightQuantizer for GptqQuantizer {
    fn name(&self) -> &str {
        "gptq"
    }

    fn quantize(&self, ctx: &StageContext, weights: &Weights) -> Result<Weights> {
        let gseqs = ctx
            .corpus
            .calib_sequences(8.min(ctx.cfg.calib_sequences), ctx.cfg.calib_seq_len);
        Ok(quant::gptq_quantize_model(
            weights,
            &gseqs,
            GptqConfig { bits: ctx.cfg.bits.w, damp: self.damp },
        ))
    }
}

/// Learnable weight clipping (OmniQuant-like).
pub struct OmniQuantQuantizer;

impl WeightQuantizer for OmniQuantQuantizer {
    fn name(&self) -> &str {
        "omniquant"
    }

    fn quantize(&self, ctx: &StageContext, weights: &Weights) -> Result<Weights> {
        Ok(quant::omniquant_quantize_model(weights, ctx.cfg.bits.w))
    }
}

/// Per-channel activation abs-max at each linear's input, captured from a
/// native forward pass — the channel-selection statistic the mixed-
/// precision quantizers (QUIK/Atom, Appendix E) need.
pub fn act_absmax(weights: &Weights, seqs: &[Vec<i32>]) -> BTreeMap<String, Vec<f32>> {
    use crate::model::{forward_one, CaptureHook, FwdOptions};
    struct Hook(BTreeMap<String, Vec<f32>>);
    impl CaptureHook for Hook {
        fn on_linear_input(&mut self, name: &str, x: &crate::tensor::Mat) {
            let e = self.0.entry(name.to_string()).or_insert_with(|| vec![0.0; x.cols]);
            for i in 0..x.rows {
                for (c, m) in e.iter_mut().enumerate() {
                    *m = m.max(x.at(i, c).abs());
                }
            }
        }
    }
    let mut hook = Hook(BTreeMap::new());
    for seq in seqs {
        forward_one(weights, seq, FwdOptions::FP, &mut hook);
    }
    hook.0
}

/// (target, capture-site) pairs for the mixed-precision quantizers: wk/wv
/// share wq's input, wu shares wg's.
fn mixed_sites(n_layers: usize) -> Vec<(String, String)> {
    let mut v = Vec::new();
    for l in 0..n_layers {
        v.push((format!("l{l}.wq"), format!("l{l}.wq")));
        v.push((format!("l{l}.wk"), format!("l{l}.wq")));
        v.push((format!("l{l}.wv"), format!("l{l}.wq")));
        v.push((format!("l{l}.wo"), format!("l{l}.wo")));
        v.push((format!("l{l}.wg"), format!("l{l}.wg")));
        v.push((format!("l{l}.wu"), format!("l{l}.wg")));
        v.push((format!("l{l}.wd"), format!("l{l}.wd")));
    }
    v
}

/// QUIK-like mixed precision: protect the top activation channels in fp,
/// quantize the rest (the paper protects 256/4096 — 1/16 of channels).
pub struct QuikQuantizer {
    /// Denominator of the protected-channel fraction (16 → 1/16).
    pub keep_divisor: usize,
}

impl Default for QuikQuantizer {
    fn default() -> Self {
        QuikQuantizer { keep_divisor: 16 }
    }
}

impl WeightQuantizer for QuikQuantizer {
    fn name(&self) -> &str {
        "quik-mixed"
    }

    fn quantize(&self, ctx: &StageContext, weights: &Weights) -> Result<Weights> {
        let absmax = act_absmax(weights, &ctx.corpus.calib_sequences(2, 128));
        let mut out = weights.clone();
        for (target, site) in mixed_sites(weights.cfg.n_layers) {
            let Some(a) = absmax.get(&site) else { continue };
            let w = out.get(&target);
            let keep = (w.cols / self.keep_divisor).max(2);
            let q = quant::quik_quantize_mat(w, a, keep, ctx.cfg.bits.w);
            out.set(&target, q);
        }
        Ok(out)
    }
}

/// Atom-like mixed precision: reordered, grouped scales with the top group
/// kept at 8 bits.
pub struct AtomQuantizer;

impl WeightQuantizer for AtomQuantizer {
    fn name(&self) -> &str {
        "atom-mixed"
    }

    fn quantize(&self, ctx: &StageContext, weights: &Weights) -> Result<Weights> {
        let absmax = act_absmax(weights, &ctx.corpus.calib_sequences(2, 128));
        let mut out = weights.clone();
        for (target, site) in mixed_sites(weights.cfg.n_layers) {
            let Some(a) = absmax.get(&site) else { continue };
            let q = quant::atom_quantize_mat(out.get(&target), a, ctx.cfg.bits.w);
            out.set(&target, q);
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// The registry.
// ---------------------------------------------------------------------------

/// One named, composed method: a rotation strategy, an optional fixed
/// weight quantizer (None = honor `PipelineConfig::weight_quant`), and
/// whether SmoothQuant scaling runs in the fuse stage.
#[derive(Clone)]
pub struct MethodSpec {
    /// Display name (the registry key; matched case-insensitively).
    pub name: String,
    /// Lowercase aliases accepted by `resolve` (e.g. "dart").
    pub aliases: Vec<String>,
    pub rotation: Arc<dyn RotationStrategy>,
    pub quantizer: Option<Arc<dyn WeightQuantizer>>,
    pub smooth: bool,
}

/// Name → method-spec registry. `builtin()` carries the eight methods of
/// Table 2; `register` adds (or replaces) entries, so out-of-tree
/// strategies run through the same pipeline without coordinator edits.
pub struct MethodRegistry {
    specs: Vec<MethodSpec>,
}

impl Default for MethodRegistry {
    fn default() -> Self {
        MethodRegistry::builtin()
    }
}

impl MethodRegistry {
    /// An empty registry (tests, fully custom method grids).
    pub fn empty() -> MethodRegistry {
        MethodRegistry { specs: Vec::new() }
    }

    /// The eight built-in methods — the rows of Table 2.
    pub fn builtin() -> MethodRegistry {
        let mut reg = MethodRegistry::empty();
        reg.register(MethodSpec {
            name: "RTN".into(),
            aliases: vec!["rtn".into()],
            rotation: Arc::new(NoRotation),
            quantizer: Some(Arc::new(RtnQuantizer)),
            smooth: false,
        });
        reg.register(MethodSpec {
            name: "SmoothQuant".into(),
            aliases: vec!["smoothquant".into(), "smooth".into()],
            rotation: Arc::new(NoRotation),
            quantizer: Some(Arc::new(RtnQuantizer)),
            smooth: true,
        });
        reg.register(MethodSpec {
            name: "GPTQ".into(),
            aliases: vec!["gptq".into()],
            rotation: Arc::new(NoRotation),
            quantizer: None, // honors weight_quant (GPTQ by default)
            smooth: false,
        });
        reg.register(MethodSpec {
            name: "OmniQuant".into(),
            aliases: vec!["omniquant".into(), "omni".into()],
            rotation: Arc::new(NoRotation),
            quantizer: Some(Arc::new(OmniQuantQuantizer)),
            smooth: false,
        });
        reg.register(MethodSpec {
            name: "QuaRot".into(),
            aliases: vec!["quarot".into()],
            rotation: Arc::new(RandomHadamard),
            quantizer: None,
            smooth: false,
        });
        reg.register(MethodSpec {
            name: "SpinQuant-sim".into(),
            aliases: vec!["spinquant".into(), "spin".into()],
            rotation: Arc::new(SpinCayley),
            quantizer: None,
            smooth: false,
        });
        reg.register(MethodSpec {
            name: "OSTQuant-sim".into(),
            aliases: vec!["ostquant".into(), "ost".into()],
            rotation: Arc::new(SpinCayley),
            quantizer: None,
            smooth: true,
        });
        reg.register(MethodSpec {
            name: "DartQuant".into(),
            aliases: vec!["dartquant".into(), "dart".into()],
            rotation: Arc::new(DartCalibrated),
            quantizer: None,
            smooth: false,
        });
        reg
    }

    /// Add a spec; an existing spec with the same (case-insensitive) name
    /// is replaced, so callers can override built-ins.
    pub fn register(&mut self, spec: MethodSpec) -> &mut MethodRegistry {
        let key = spec.name.to_ascii_lowercase();
        self.specs.retain(|s| s.name.to_ascii_lowercase() != key);
        self.specs.push(spec);
        self
    }

    /// Look a method up by display name or alias (case-insensitive).
    pub fn resolve(&self, name: &str) -> Result<&MethodSpec> {
        let key = name.to_ascii_lowercase();
        self.specs
            .iter()
            .find(|s| s.name.to_ascii_lowercase() == key || s.aliases.iter().any(|a| *a == key))
            .with_context(|| {
                format!("unknown method {name:?} (registered: {})", self.names().join(", "))
            })
    }

    /// Registered display names, in registration order.
    pub fn names(&self) -> Vec<&str> {
        self.specs.iter().map(|s| s.name.as_str()).collect()
    }

    pub fn specs(&self) -> &[MethodSpec] {
        &self.specs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_registry_has_all_eight_methods() {
        let reg = MethodRegistry::builtin();
        assert_eq!(reg.names().len(), super::super::Method::ALL.len());
        for m in super::super::Method::ALL {
            assert_eq!(reg.resolve(m.name()).unwrap().name, m.name());
        }
        assert!(reg.resolve("awq").is_err());
    }

    #[test]
    fn aliases_resolve_case_insensitively() {
        let reg = MethodRegistry::builtin();
        assert_eq!(reg.resolve("DART").unwrap().name, "DartQuant");
        assert_eq!(reg.resolve("Smooth").unwrap().name, "SmoothQuant");
        assert_eq!(reg.resolve("spinquant-SIM").unwrap().name, "SpinQuant-sim");
    }

    #[test]
    fn register_replaces_same_name() {
        let mut reg = MethodRegistry::builtin();
        let n = reg.names().len();
        reg.register(MethodSpec {
            name: "rtn".into(), // replaces the builtin RTN, case-insensitive
            aliases: vec![],
            rotation: Arc::new(RandomOrthogonal),
            quantizer: None,
            smooth: false,
        });
        assert_eq!(reg.names().len(), n);
        assert_eq!(reg.resolve("rtn").unwrap().rotation.name(), "random-orthogonal");
    }

    #[test]
    fn mixed_sites_cover_every_linear() {
        let sites = mixed_sites(2);
        assert_eq!(sites.len(), 14);
        assert!(sites.iter().any(|(t, s)| t == "l1.wu" && s == "l1.wg"));
    }
}
