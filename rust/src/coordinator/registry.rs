//! The open method space: `RotationStrategy` × `WeightQuantizer` traits,
//! the built-in implementations (the rows of Table 2), and the
//! `MethodRegistry` mapping names/aliases → composed method specs.
//!
//! DartQuant's own contribution (whip + QR-Orth calibration) is just one
//! `RotationStrategy`; new baselines (DFRot-style refined rotations,
//! ConQuR-style corner objectives) plug in by registering a spec — the
//! coordinator's hot path never changes.

use super::budget::MemoryGate;
use super::capture::{self, CalibrationPools};
use super::report::{PipelineEvent, PipelineObserver};
use super::scheduler::{CalibJob, Scheduler};
use super::{job_bytes, spin_job_bytes, PipelineConfig};
use crate::calib::{self, CalibConfig};
use crate::data::Corpus;
use crate::model::{Tensor, TokenBatch, WeightStore, Weights};
use crate::quant::{self, GptqConfig};
use crate::rotation::RotationSet;
use crate::runtime::{with_thread_runtime, Runtime};
use crate::tensor::{shard_ranges, Mat, QMat, QuantSpec};
use crate::util::prng::Pcg64;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

// ---------------------------------------------------------------------------
// Stage context — what every strategy/quantizer sees.
// ---------------------------------------------------------------------------

/// Everything a pipeline stage may need. Strategies are stateless trait
/// objects; all run-specific knobs come through here.
pub struct StageContext<'a> {
    /// PJRT runtime; `None` for native-only runs. Strategies that need
    /// AOT artifacts call [`StageContext::runtime`] and surface a
    /// contextful error when absent.
    pub rt: Option<&'a Runtime>,
    /// The run's full configuration (method, bits, calibration knobs,
    /// worker count).
    pub cfg: &'a PipelineConfig,
    /// The unquantized model the pipeline started from.
    pub weights: &'a Weights,
    /// The calibration corpus for `cfg.calib_dialect`.
    pub corpus: &'a Corpus,
    /// The run's memory-admission gate, shared with the scheduler.
    pub gate: Arc<MemoryGate>,
    /// The run's event observer, shared with the scheduler.
    pub observer: Arc<dyn PipelineObserver>,
}

impl StageContext<'_> {
    /// The PJRT runtime, or a contextful error pointing at
    /// `make artifacts` when the pipeline runs natively.
    pub fn runtime(&self) -> Result<&Runtime> {
        self.rt.context(
            "this stage needs the PJRT runtime (run `make artifacts`, then use Pipeline::run)",
        )
    }

    /// Forward one event to the run's observer (stage-thread emission;
    /// scheduler jobs buffer through [`super::JobSink`] instead).
    pub fn emit(&self, event: PipelineEvent) {
        self.observer.on_event(&event);
    }
}

// ---------------------------------------------------------------------------
// Trait families.
// ---------------------------------------------------------------------------

/// What a rotation-calibration stage produced.
pub struct RotationOutcome {
    pub rotation: Option<RotationSet>,
    /// Loss trajectories (R1 first, then R2 per layer) for methods that
    /// optimize; empty for closed-form strategies.
    pub loss_curves: Vec<Vec<f32>>,
}

impl RotationOutcome {
    /// No rotation, no loss curves (non-rotating methods).
    pub fn none() -> RotationOutcome {
        RotationOutcome { rotation: None, loss_curves: Vec::new() }
    }

    /// A rotation with no loss curves (closed-form strategies).
    pub fn some(rotation: RotationSet) -> RotationOutcome {
        RotationOutcome { rotation: Some(rotation), loss_curves: Vec::new() }
    }
}

/// How the rotation set is produced — the open axis of the method space.
/// Out-of-tree strategies implement this and register a [`MethodSpec`];
/// the coordinator never needs editing.
pub trait RotationStrategy: Send + Sync {
    /// Stable strategy name used in reports and `info` listings.
    fn name(&self) -> &str;

    /// Capture-stage work (activation pools for pool-based calibration).
    /// Default: nothing to capture.
    fn capture(&self, _ctx: &StageContext) -> Result<Option<CalibrationPools>> {
        Ok(None)
    }

    /// Calibrate-stage work: produce the rotation set (`None` rotation =
    /// the method does not rotate).
    fn calibrate(
        &self,
        ctx: &StageContext,
        pools: Option<&CalibrationPools>,
    ) -> Result<RotationOutcome>;

    /// Capture-stage work for **streamed** (out-of-core) runs: pools must
    /// come from the [`WeightStore`], never from `ctx.weights` (the
    /// streamed driver routes all tensor access through checkout leases —
    /// see `docs/STREAMING.md`). The default declines streaming with a
    /// contextful error; strategies whose [`RotationStrategy::capture`]
    /// is a no-op override this to return `Ok(None)`, and capturing
    /// strategies run a layer-streamed capture. `calibrate` is reused
    /// unchanged — it operates on pools, not weights.
    fn capture_streamed(
        &self,
        _ctx: &StageContext,
        _store: &WeightStore,
    ) -> Result<Option<CalibrationPools>> {
        anyhow::bail!(
            "rotation strategy {:?} does not support streamed (out-of-core) execution — \
             run without --streaming",
            self.name()
        )
    }
}

/// How weights are quantized after rotation fusion.
pub trait WeightQuantizer: Send + Sync {
    /// Stable quantizer name used in reports and `info` listings.
    fn name(&self) -> &str;

    /// Quantize `weights` (already rotated/smoothed) at `ctx.cfg.bits.w`
    /// bits. With `ctx.cfg.packed` (and a packable bit width) the
    /// transformer linears come back as packed `QMat` storage; otherwise
    /// the historical dequantized-f32 model.
    fn quantize(&self, ctx: &StageContext, weights: &Weights) -> Result<Weights>;

    /// Quantize for **streamed** (out-of-core) runs: check weights out of
    /// the store, quantize with the same kernels as
    /// [`WeightQuantizer::quantize`], write back — the output model must
    /// be bit-identical to the in-memory pass (the determinism contract
    /// of `docs/STREAMING.md`). The default declines streaming with a
    /// contextful error. Built-ins: RTN and GPTQ stream a layer at a
    /// time; OmniQuant fans out per-layer scheduler jobs whose
    /// checkout/checkin leases bound residency; the mixed-precision
    /// quantizers (QUIK/Atom) do not stream yet.
    fn quantize_streamed(&self, _ctx: &StageContext, _store: &WeightStore) -> Result<()> {
        anyhow::bail!(
            "weight quantizer {:?} does not support streamed (out-of-core) execution — \
             run without --streaming",
            self.name()
        )
    }
}

/// Whether this run emits packed storage: the `--packed` switch and a
/// bit width the packed representation covers.
fn packed_run(cfg: &PipelineConfig) -> bool {
    cfg.packed && QuantSpec::supports(cfg.bits.w)
}

// ---------------------------------------------------------------------------
// Built-in rotation strategies.
// ---------------------------------------------------------------------------

/// No rotation (RTN / SmoothQuant / GPTQ / OmniQuant baselines).
pub struct NoRotation;

impl RotationStrategy for NoRotation {
    fn name(&self) -> &str {
        "none"
    }

    fn calibrate(
        &self,
        _ctx: &StageContext,
        _pools: Option<&CalibrationPools>,
    ) -> Result<RotationOutcome> {
        Ok(RotationOutcome::none())
    }

    fn capture_streamed(
        &self,
        _ctx: &StageContext,
        _store: &WeightStore,
    ) -> Result<Option<CalibrationPools>> {
        Ok(None) // nothing to capture — streams trivially
    }
}

/// Random-Hadamard R1/R2 (+ online R3/R4) — QuaRot.
pub struct RandomHadamard;

impl RotationStrategy for RandomHadamard {
    fn name(&self) -> &str {
        "random-hadamard"
    }

    fn calibrate(
        &self,
        ctx: &StageContext,
        _pools: Option<&CalibrationPools>,
    ) -> Result<RotationOutcome> {
        let cfg = &ctx.weights.cfg;
        let mut rng = Pcg64::new(ctx.cfg.seed ^ 0x707);
        Ok(RotationOutcome::some(RotationSet::random_hadamard(
            cfg.dim,
            cfg.head_dim,
            cfg.n_layers,
            &mut rng,
        )))
    }

    fn capture_streamed(
        &self,
        _ctx: &StageContext,
        _store: &WeightStore,
    ) -> Result<Option<CalibrationPools>> {
        Ok(None) // rotations are data-free — streams trivially
    }
}

/// Haar-random orthogonal rotations — the ablation QuaRot found inferior
/// to Hadamard (kept as a registered strategy for the method grid).
pub struct RandomOrthogonal;

impl RotationStrategy for RandomOrthogonal {
    fn name(&self) -> &str {
        "random-orthogonal"
    }

    fn calibrate(
        &self,
        ctx: &StageContext,
        _pools: Option<&CalibrationPools>,
    ) -> Result<RotationOutcome> {
        let cfg = &ctx.weights.cfg;
        let mut rng = Pcg64::new(ctx.cfg.seed ^ 0x707);
        Ok(RotationOutcome::some(RotationSet::random_orthogonal(
            cfg.dim,
            cfg.head_dim,
            cfg.n_layers,
            &mut rng,
        )))
    }

    fn capture_streamed(
        &self,
        _ctx: &StageContext,
        _store: &WeightStore,
    ) -> Result<Option<CalibrationPools>> {
        Ok(None) // rotations are data-free — streams trivially
    }
}

/// End-to-end Cayley fine-tuning of R1 (SpinQuant-sim; + smooth scales =
/// OSTQuant-sim). ONE job holding the whole model + optimizer + backprop
/// state; charged in full against the memory gate — Table 3's resource
/// story.
pub struct SpinCayley;

impl RotationStrategy for SpinCayley {
    fn name(&self) -> &str {
        "spin-cayley"
    }

    fn calibrate(
        &self,
        ctx: &StageContext,
        _pools: Option<&CalibrationPools>,
    ) -> Result<RotationOutcome> {
        let rt = ctx.runtime()?;
        let model_cfg = ctx.weights.cfg.clone();
        let need = spin_job_bytes(&model_cfg);
        // ONE monolithic job: bracket it with the same JobStarted/Finished
        // events the scheduler emits — including JobFinished { ok: false }
        // on admission/optimizer failure — so observers always see a
        // balanced stream.
        ctx.emit(PipelineEvent::JobStarted { job: 0, label: "spin-e2e".into() });
        let t0 = Instant::now();
        let dialect = ctx.cfg.calib_dialect;
        let (vocab, seq_len) = (model_cfg.vocab, ctx.cfg.calib_seq_len);
        let result = (|| {
            let _lease = ctx.gate.admit(need).map_err(|e| {
                anyhow::anyhow!("{} cannot run under this memory budget: {e}", self.name())
            })?;
            ctx.emit(PipelineEvent::JobAdmitted { job: 0, bytes: need });
            calib::spin_calibrate(rt, ctx.weights, &ctx.cfg.spin, move |step| {
                let c = Corpus::new(dialect, vocab, 7);
                TokenBatch::new(&c.calib_sequences_at(8, seq_len, step as u64))
            })
        })();
        if let Ok(res) = &result {
            for (step, &loss) in res.losses.iter().enumerate() {
                ctx.emit(PipelineEvent::LossTick { job: 0, step, loss });
            }
        }
        ctx.emit(PipelineEvent::JobFinished {
            job: 0,
            elapsed: t0.elapsed(),
            ok: result.is_ok(),
        });
        let res = result?;
        let mut rng = Pcg64::new(ctx.cfg.seed ^ 0x707);
        let rotation = RotationSet {
            r1: res.r1,
            r2: (0..model_cfg.n_layers)
                .map(|_| crate::linalg::randomized_hadamard(model_cfg.head_dim, &mut rng))
                .collect(),
            online_had: true,
        };
        Ok(RotationOutcome { rotation: Some(rotation), loss_curves: vec![res.losses] })
    }

    fn capture_streamed(
        &self,
        _ctx: &StageContext,
        _store: &WeightStore,
    ) -> Result<Option<CalibrationPools>> {
        anyhow::bail!(
            "end-to-end Cayley fine-tuning ({}) holds the whole model + optimizer + backprop \
             state at once — exactly the workload a resident budget exists to reject (the \
             paper's Table 3 wall); run without --streaming, or use a per-layer method like \
             dartquant",
            self.name()
        )
    }
}

/// Whip + QR-Orth rotational distribution calibration — the paper.
/// Capture (data-plane) then R1 + per-layer R2 jobs on the worker pool,
/// each admitted individually by the memory gate.
pub struct DartCalibrated;

impl RotationStrategy for DartCalibrated {
    fn name(&self) -> &str {
        "dart-calibrated"
    }

    fn capture(&self, ctx: &StageContext) -> Result<Option<CalibrationPools>> {
        // calibrate() executes AOT artifacts on per-worker runtimes, so a
        // native run can never succeed — fail here, before the expensive
        // capture forward passes, with the contextful runtime error.
        let rt = ctx.runtime()?;
        let calib_seqs =
            ctx.corpus.calib_sequences(ctx.cfg.calib_sequences, ctx.cfg.calib_seq_len);
        Ok(Some(capture::capture_pools(
            rt,
            ctx.weights,
            &calib_seqs,
            ctx.cfg.token_frac,
            ctx.cfg.seed,
        )?))
    }

    fn calibrate(
        &self,
        ctx: &StageContext,
        pools: Option<&CalibrationPools>,
    ) -> Result<RotationOutcome> {
        let pools = pools.context("DartCalibrated needs the capture stage's activation pools")?;
        // Belt-and-braces: capture() already failed native runs, but a
        // caller handing pools in directly still gets the contextful
        // runtime error instead of a raw artifact-open failure from a
        // worker thread.
        ctx.runtime()?;
        let model_cfg = ctx.weights.cfg.clone();
        let dir = ctx.cfg.artifacts_dir.clone();
        // Decompose into independent scheduler jobs over the *borrowed*
        // pools (no cloning): R1 is job 0, layer l's R2 is job l + 1, each
        // drawing its PRNG stream from `calib.seed ⊕ id` so any worker
        // count produces bit-identical rotations.
        let mut jobs: Vec<CalibJob<(&crate::tensor::Mat, CalibConfig)>> =
            Vec::with_capacity(model_cfg.n_layers + 1);
        jobs.push(CalibJob::new(
            0,
            "r1",
            job_bytes(&pools.r1_pool),
            (&pools.r1_pool, ctx.cfg.calib.clone()),
        ));
        for (l, p) in pools.r2_pools.iter().enumerate() {
            let mut c2 = ctx.cfg.calib.clone();
            c2.lr = 1e-3; // paper Table 23: R2 uses lr 1e-3
            // R2 jobs always use whip (the ablation objectives are emitted
            // only at the R1 dims; matches the paper, which ablates the R1
            // objective only).
            c2.objective = crate::calib::Objective::Whip;
            jobs.push(CalibJob::new(l + 1, format!("r2[{l}]"), job_bytes(p), (p, c2)));
        }
        let base_seed = ctx.cfg.calib.seed;
        for job in &mut jobs {
            let per_job = job.seed(base_seed);
            job.payload.1.seed = per_job;
        }
        let results = Scheduler::new(ctx.cfg.workers).run(
            &ctx.gate,
            ctx.observer.as_ref(),
            jobs,
            |job, sink| {
                let (pool_mat, ccfg) = (job.payload.0, &job.payload.1);
                let r = with_thread_runtime(&dir, |rt| {
                    calib::calibrate_rotation(rt, pool_mat, ccfg)
                })??;
                for (step, &loss) in r.losses.iter().enumerate() {
                    sink.emit(PipelineEvent::LossTick { job: job.id, step, loss });
                }
                Ok(r)
            },
        )?;
        // Scheduler results come back in job order: R1 first, then the
        // per-layer R2s.
        let mut results = results.into_iter();
        let r1 = results.next().context("no calibrated R1")?;
        let mut loss_curves = vec![r1.losses.clone()];
        let mut r2 = Vec::with_capacity(model_cfg.n_layers);
        for r in results {
            loss_curves.push(r.losses.clone());
            r2.push(r.rotation);
        }
        anyhow::ensure!(
            r2.len() == model_cfg.n_layers,
            "scheduler returned {} R2 rotations, model has {} layers",
            r2.len(),
            model_cfg.n_layers
        );
        let rotation = RotationSet { r1: r1.rotation, r2, online_had: true };
        Ok(RotationOutcome { rotation: Some(rotation), loss_curves })
    }

    fn capture_streamed(
        &self,
        ctx: &StageContext,
        store: &WeightStore,
    ) -> Result<Option<CalibrationPools>> {
        // Calibration still executes AOT artifacts on per-worker runtimes,
        // so fail native runs here — before the capture forward passes —
        // with the contextful runtime error.
        ctx.runtime()?;
        let calib_seqs =
            ctx.corpus.calib_sequences(ctx.cfg.calib_sequences, ctx.cfg.calib_seq_len);
        Ok(Some(capture::capture_pools_streamed(
            store,
            &calib_seqs,
            ctx.cfg.token_frac,
            ctx.cfg.seed,
        )?))
    }
}

// ---------------------------------------------------------------------------
// Built-in weight quantizers.
// ---------------------------------------------------------------------------

/// Per-output-channel symmetric RTN — the paper's weight quantizer.
pub struct RtnQuantizer;

impl WeightQuantizer for RtnQuantizer {
    fn name(&self) -> &str {
        "rtn"
    }

    fn quantize(&self, ctx: &StageContext, weights: &Weights) -> Result<Weights> {
        Ok(if packed_run(ctx.cfg) {
            quant::rtn_quantize_model_packed(weights, ctx.cfg.bits.w)
        } else {
            quant::rtn_quantize_model(weights, ctx.cfg.bits.w)
        })
    }

    fn quantize_streamed(&self, ctx: &StageContext, store: &WeightStore) -> Result<()> {
        quant::rtn_quantize_store(store, ctx.cfg.bits.w, packed_run(ctx.cfg))
    }
}

/// GPTQ with Hessian capture over calibration sequences.
pub struct GptqQuantizer {
    /// Hessian damping factor (fraction of the mean diagonal).
    pub damp: f32,
}

impl Default for GptqQuantizer {
    fn default() -> Self {
        GptqQuantizer { damp: 0.01 }
    }
}

impl WeightQuantizer for GptqQuantizer {
    fn name(&self) -> &str {
        "gptq"
    }

    fn quantize(&self, ctx: &StageContext, weights: &Weights) -> Result<Weights> {
        let gseqs = ctx
            .corpus
            .calib_sequences(8.min(ctx.cfg.calib_sequences), ctx.cfg.calib_seq_len);
        let cfg = GptqConfig { bits: ctx.cfg.bits.w, damp: self.damp };
        if cfg.bits >= 16 {
            // Identity grid (same early-out as gptq_quantize_layer); skip
            // the capture passes entirely.
            return Ok(weights.clone());
        }
        let packed = packed_run(ctx.cfg);
        let shards = ctx.cfg.shards.max(1);
        // Hessian capture stays sequential: the f32 `H += XᵀX`
        // accumulation order is part of the determinism contract
        // (docs/CONCURRENCY.md) and is never sharded.
        let hessians = quant::gptq_capture_hessians(weights, &gseqs);
        // Per-target setup (dampening + Cholesky + per-row scales) on the
        // stage thread; only the row-independent error propagation fans
        // out through the scheduler below.
        let mut plans: Vec<(String, Mat, Vec<f32>)> = Vec::new();
        for l in 0..weights.cfg.n_layers {
            for (site, targets) in quant::gptq_sites(l) {
                let Some(h) = hessians.get(&site) else { continue };
                for t in targets {
                    let (lmat, scales) = quant::gptq_prepare(weights.get(&t), h, cfg);
                    plans.push((t, lmat, scales));
                }
            }
        }
        // One scheduler job per (target, row shard). Each sub-job reads
        // and produces only its row slice, so the gate charges the
        // per-shard working set (slice in + slice out) instead of
        // whole-layer buffers.
        let mut jobs: Vec<CalibJob<(usize, usize, usize)>> = Vec::new();
        for (p, (target, _, _)) in plans.iter().enumerate() {
            let w = weights.get(target);
            let ranges = shard_ranges(w.rows, shards);
            let multi = ranges.len() > 1;
            for (s, (lo, hi)) in ranges.into_iter().enumerate() {
                let label = if multi {
                    format!("gptq[{target}#s{s}]")
                } else {
                    format!("gptq[{target}]")
                };
                let bytes = ((hi - lo) * w.cols * 4 * 2) as u64;
                jobs.push(CalibJob::new(jobs.len(), label, bytes, (p, lo, hi)));
            }
        }
        let results = Scheduler::new(ctx.cfg.workers).run(
            &ctx.gate,
            ctx.observer.as_ref(),
            jobs,
            |job, _sink| {
                let (p, lo, hi) = job.payload;
                let (target, lmat, scales) = &plans[p];
                Ok((
                    p,
                    quant::gptq_propagate_rows(weights.get(target), lmat, scales, cfg, lo, hi),
                ))
            },
        )?;
        // Stitch the row blocks back in job order — per plan the shard
        // ranges were emitted ascending, so appending reconstructs the
        // propagated matrix bit-for-bit — then snap/encode once per
        // target, the identical tail to gptq_quantize_layer(_qmat).
        let mut working: BTreeMap<usize, Mat> = BTreeMap::new();
        for (p, block) in results {
            use std::collections::btree_map::Entry;
            match working.entry(p) {
                Entry::Vacant(e) => {
                    e.insert(block);
                }
                Entry::Occupied(mut e) => {
                    let m = e.get_mut();
                    m.data.extend_from_slice(&block.data);
                    m.rows += block.rows;
                }
            }
        }
        let mut out = weights.clone();
        for (p, (target, _, scales)) in plans.iter().enumerate() {
            let wmat = working.remove(&p).expect("every shard job ran");
            debug_assert_eq!(wmat.shape(), weights.get(target).shape());
            if QuantSpec::supports(cfg.bits) {
                let q =
                    QMat::quantize_with_scales(&wmat, QuantSpec::new(cfg.bits), scales.clone());
                q.prepack();
                if packed {
                    out.set_packed(target, q);
                } else {
                    out.set(target, q.dequantize());
                }
            } else {
                let mut m = wmat;
                quant::gptq_snap_wide(&mut m, scales, cfg.bits);
                out.set(target, m);
            }
        }
        Ok(out)
    }

    fn quantize_streamed(&self, ctx: &StageContext, store: &WeightStore) -> Result<()> {
        let gseqs = ctx
            .corpus
            .calib_sequences(8.min(ctx.cfg.calib_sequences), ctx.cfg.calib_seq_len);
        let cfg = GptqConfig { bits: ctx.cfg.bits.w, damp: self.damp };
        quant::gptq_quantize_store(store, &gseqs, cfg, packed_run(ctx.cfg))
    }
}

/// Learnable weight clipping (OmniQuant-like). The per-channel clip-ratio
/// grid search is independent per weight matrix, so the quantize stage
/// fans out one scheduler job per layer (same gate/event regime as
/// rotation calibration).
pub struct OmniQuantQuantizer;

impl WeightQuantizer for OmniQuantQuantizer {
    fn name(&self) -> &str {
        "omniquant"
    }

    fn quantize(&self, ctx: &StageContext, weights: &Weights) -> Result<Weights> {
        let bits = ctx.cfg.bits.w;
        let packed = packed_run(ctx.cfg);
        if ctx.cfg.shards > 1 && bits < 16 {
            return omniquant_quantize_sharded(ctx, weights, bits, packed, ctx.cfg.shards);
        }
        // Group transformer weights by layer prefix ("l3.wq" → "l3");
        // unprefixed weights (final norm, …) form their own groups.
        let mut groups: BTreeMap<String, Vec<String>> = BTreeMap::new();
        for n in weights.names() {
            if n == "embed" || n == "head" {
                continue;
            }
            let key = n.split('.').next().unwrap_or(n).to_string();
            groups.entry(key).or_default().push(n.clone());
        }
        let jobs: Vec<CalibJob<Vec<String>>> = groups
            .into_iter()
            .enumerate()
            .map(|(i, (key, names))| {
                // Dense runs charge the historical input bytes; --packed
                // runs additionally charge the packed output the job
                // materializes, so the gate accounts true packed bytes.
                let bytes: u64 = names
                    .iter()
                    .map(|n| {
                        let m = weights.get(n);
                        let out = if packed {
                            QMat::packed_estimate(m.rows, m.cols, QuantSpec::new(bits))
                        } else {
                            0
                        };
                        m.nbytes() + out
                    })
                    .sum();
                CalibJob::new(i, format!("omniquant[{key}]"), bytes, names)
            })
            .collect();
        let results = Scheduler::new(ctx.cfg.workers).run(
            &ctx.gate,
            ctx.observer.as_ref(),
            jobs,
            |job, _sink| {
                Ok(job
                    .payload
                    .iter()
                    .map(|n| {
                        let m = weights.get(n);
                        let t = if packed {
                            Tensor::Packed(quant::omniquant_quantize_qmat(m, bits))
                        } else {
                            Tensor::F32(quant::omniquant_quantize_mat(m, bits))
                        };
                        (n.clone(), t)
                    })
                    .collect::<Vec<_>>())
            },
        )?;
        let mut out = weights.clone();
        for group in results {
            for (n, t) in group {
                out.set_tensor(&n, t);
            }
        }
        Ok(out)
    }

    /// The streamed form of the same fan-out: identical job
    /// decomposition, labels and gate charges, but each scheduler job
    /// checks its layer's weights out of the store, quantizes them with
    /// the same per-matrix search, and writes them back — so the store's
    /// resident budget (not the worker count) bounds how many layers'
    /// weights are in flight.
    fn quantize_streamed(&self, ctx: &StageContext, store: &WeightStore) -> Result<()> {
        let bits = ctx.cfg.bits.w;
        let packed = packed_run(ctx.cfg);
        let model_cfg = store.cfg();
        let mut groups: BTreeMap<String, Vec<String>> = BTreeMap::new();
        for n in model_cfg.param_names() {
            if n == "embed" || n == "head" {
                continue;
            }
            let key = n.split('.').next().unwrap_or(&n).to_string();
            groups.entry(key).or_default().push(n);
        }
        let jobs: Vec<CalibJob<Vec<String>>> = groups
            .into_iter()
            .enumerate()
            .map(|(i, (key, names))| {
                // Same charge as the in-memory jobs: dense input bytes,
                // plus the packed output a --packed job materializes.
                let bytes: u64 = names
                    .iter()
                    .map(|n| {
                        let (r, c) = model_cfg.param_shape(n);
                        let out = if packed {
                            QMat::packed_estimate(r, c, QuantSpec::new(bits))
                        } else {
                            0
                        };
                        (r * c * 4) as u64 + out
                    })
                    .sum();
                CalibJob::new(i, format!("omniquant[{key}]"), bytes, names)
            })
            .collect();
        Scheduler::new(ctx.cfg.workers).run(
            &ctx.gate,
            ctx.observer.as_ref(),
            jobs,
            |job, _sink| {
                let mut lease = store.checkout(&job.payload)?;
                let w = lease.weights_mut();
                for n in &job.payload {
                    if packed {
                        let q = quant::omniquant_quantize_qmat(w.get(n), bits);
                        w.set_packed(n, q);
                    } else {
                        let q = quant::omniquant_quantize_mat(w.get(n), bits);
                        w.set(n, q);
                    }
                }
                lease.commit()?;
                Ok(())
            },
        )?;
        Ok(())
    }
}

/// The `--shards > 1` form of [`OmniQuantQuantizer::quantize`]: one
/// scheduler job per (tensor, row range). The clip-ratio grid search is
/// per-row separable, so each sub-job searches only its row slice with
/// `clipped_scales_range` and the stage thread concatenates the slices in
/// range order before the shared QMat encode — bit-identical weights to
/// the unsharded path, with the gate charging per-shard working sets
/// instead of whole layer groups.
fn omniquant_quantize_sharded(
    ctx: &StageContext,
    weights: &Weights,
    bits: u8,
    packed: bool,
    shards: usize,
) -> Result<Weights> {
    let qmax = quant::clip_qmax(bits);
    let mut names: Vec<String> = Vec::new();
    for n in weights.names() {
        if n == "embed" || n == "head" {
            continue;
        }
        names.push(n.clone());
    }
    let mut jobs: Vec<CalibJob<(usize, usize, usize)>> = Vec::new();
    for (t, n) in names.iter().enumerate() {
        let m = weights.get(n);
        // Per-shard charge: the row slice of the historical whole-tensor
        // bytes (dense input plus, for --packed runs, the packed output
        // those rows produce).
        let whole = m.nbytes()
            + if packed {
                QMat::packed_estimate(m.rows, m.cols, QuantSpec::new(bits))
            } else {
                0
            };
        for (s, (lo, hi)) in shard_ranges(m.rows, shards).into_iter().enumerate() {
            let bytes = (whole * (hi - lo) as u64 / m.rows.max(1) as u64).max(1);
            jobs.push(CalibJob::new(
                jobs.len(),
                format!("omniquant[{n}#s{s}]"),
                bytes,
                (t, lo, hi),
            ));
        }
    }
    let results = Scheduler::new(ctx.cfg.workers).run(
        &ctx.gate,
        ctx.observer.as_ref(),
        jobs,
        |job, _sink| {
            let (t, lo, hi) = job.payload;
            Ok((t, quant::clipped_scales_range(weights.get(&names[t]), qmax, lo, hi)))
        },
    )?;
    // Concatenate scale slices in job order (shard ranges are emitted
    // ascending per tensor), then encode each tensor exactly as
    // omniquant_quantize_qmat / omniquant_quantize_mat would.
    let mut scales: BTreeMap<usize, Vec<f32>> = BTreeMap::new();
    for (t, part) in results {
        scales.entry(t).or_default().extend(part);
    }
    let mut out = weights.clone();
    for (t, n) in names.iter().enumerate() {
        let m = weights.get(n);
        let sc = scales.remove(&t).expect("every tensor searched");
        debug_assert_eq!(sc.len(), m.rows);
        if QuantSpec::supports(bits) {
            let q = QMat::quantize_with_scales(m, QuantSpec::new(bits), sc);
            q.prepack();
            if packed {
                out.set_packed(n, q);
            } else {
                out.set(n, q.dequantize());
            }
        } else {
            out.set(n, quant::omniquant_snap_wide(m, &sc, bits));
        }
    }
    Ok(out)
}

/// Per-channel activation abs-max at each linear's input, captured from a
/// native forward pass — the channel-selection statistic the mixed-
/// precision quantizers (QUIK/Atom, Appendix E) need.
pub fn act_absmax(weights: &Weights, seqs: &[Vec<i32>]) -> BTreeMap<String, Vec<f32>> {
    use crate::model::{forward_one, CaptureHook, FwdOptions};
    struct Hook(BTreeMap<String, Vec<f32>>);
    impl CaptureHook for Hook {
        fn on_linear_input(&mut self, name: &str, x: &crate::tensor::Mat) {
            let e = self.0.entry(name.to_string()).or_insert_with(|| vec![0.0; x.cols]);
            for i in 0..x.rows {
                for (c, m) in e.iter_mut().enumerate() {
                    *m = m.max(x.at(i, c).abs());
                }
            }
        }
    }
    let mut hook = Hook(BTreeMap::new());
    for seq in seqs {
        forward_one(weights, seq, FwdOptions::FP, &mut hook);
    }
    hook.0
}

/// (target, capture-site) pairs for the mixed-precision quantizers: wk/wv
/// share wq's input, wu shares wg's.
fn mixed_sites(n_layers: usize) -> Vec<(String, String)> {
    let mut v = Vec::new();
    for l in 0..n_layers {
        v.push((format!("l{l}.wq"), format!("l{l}.wq")));
        v.push((format!("l{l}.wk"), format!("l{l}.wq")));
        v.push((format!("l{l}.wv"), format!("l{l}.wq")));
        v.push((format!("l{l}.wo"), format!("l{l}.wo")));
        v.push((format!("l{l}.wg"), format!("l{l}.wg")));
        v.push((format!("l{l}.wu"), format!("l{l}.wg")));
        v.push((format!("l{l}.wd"), format!("l{l}.wd")));
    }
    v
}

/// QUIK-like mixed precision: protect the top activation channels in fp,
/// quantize the rest (the paper protects 256/4096 — 1/16 of channels).
pub struct QuikQuantizer {
    /// Denominator of the protected-channel fraction (16 → 1/16).
    pub keep_divisor: usize,
}

impl Default for QuikQuantizer {
    fn default() -> Self {
        QuikQuantizer { keep_divisor: 16 }
    }
}

impl WeightQuantizer for QuikQuantizer {
    fn name(&self) -> &str {
        "quik-mixed"
    }

    fn quantize(&self, ctx: &StageContext, weights: &Weights) -> Result<Weights> {
        let absmax = act_absmax(weights, &ctx.corpus.calib_sequences(2, 128));
        let packed = packed_run(ctx.cfg);
        let mut out = weights.clone();
        for (target, site) in mixed_sites(weights.cfg.n_layers) {
            let Some(a) = absmax.get(&site) else { continue };
            let keep = (out.get(&target).cols / self.keep_divisor).max(2);
            if packed {
                let q = quant::quik_quantize_qmat(out.get(&target), a, keep, ctx.cfg.bits.w);
                out.set_packed(&target, q);
            } else {
                let q = quant::quik_quantize_mat(out.get(&target), a, keep, ctx.cfg.bits.w);
                out.set(&target, q);
            }
        }
        Ok(out)
    }
}

/// Atom-like mixed precision: reordered, grouped scales with the top group
/// kept at 8 bits.
pub struct AtomQuantizer;

impl WeightQuantizer for AtomQuantizer {
    fn name(&self) -> &str {
        "atom-mixed"
    }

    fn quantize(&self, ctx: &StageContext, weights: &Weights) -> Result<Weights> {
        let absmax = act_absmax(weights, &ctx.corpus.calib_sequences(2, 128));
        let packed = packed_run(ctx.cfg);
        let mut out = weights.clone();
        for (target, site) in mixed_sites(weights.cfg.n_layers) {
            let Some(a) = absmax.get(&site) else { continue };
            if packed {
                let q = quant::atom_quantize_qmat(out.get(&target), a, ctx.cfg.bits.w);
                out.set_packed(&target, q);
            } else {
                let q = quant::atom_quantize_mat(out.get(&target), a, ctx.cfg.bits.w);
                out.set(&target, q);
            }
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// The registry.
// ---------------------------------------------------------------------------

/// One named, composed method: a rotation strategy, an optional fixed
/// weight quantizer (None = honor `PipelineConfig::weight_quant`), and
/// whether SmoothQuant scaling runs in the fuse stage.
#[derive(Clone)]
pub struct MethodSpec {
    /// Display name (the registry key; matched case-insensitively).
    pub name: String,
    /// Lowercase aliases accepted by `resolve` (e.g. "dart").
    pub aliases: Vec<String>,
    pub rotation: Arc<dyn RotationStrategy>,
    pub quantizer: Option<Arc<dyn WeightQuantizer>>,
    pub smooth: bool,
}

/// Name → method-spec registry. `builtin()` carries the eight methods of
/// Table 2; `register` adds (or replaces) entries, so out-of-tree
/// strategies run through the same pipeline without coordinator edits.
pub struct MethodRegistry {
    specs: Vec<MethodSpec>,
}

impl Default for MethodRegistry {
    fn default() -> Self {
        MethodRegistry::builtin()
    }
}

impl MethodRegistry {
    /// An empty registry (tests, fully custom method grids).
    pub fn empty() -> MethodRegistry {
        MethodRegistry { specs: Vec::new() }
    }

    /// The eight built-in methods — the rows of Table 2.
    pub fn builtin() -> MethodRegistry {
        let mut reg = MethodRegistry::empty();
        reg.register(MethodSpec {
            name: "RTN".into(),
            aliases: vec!["rtn".into()],
            rotation: Arc::new(NoRotation),
            quantizer: Some(Arc::new(RtnQuantizer)),
            smooth: false,
        });
        reg.register(MethodSpec {
            name: "SmoothQuant".into(),
            aliases: vec!["smoothquant".into(), "smooth".into()],
            rotation: Arc::new(NoRotation),
            quantizer: Some(Arc::new(RtnQuantizer)),
            smooth: true,
        });
        reg.register(MethodSpec {
            name: "GPTQ".into(),
            aliases: vec!["gptq".into()],
            rotation: Arc::new(NoRotation),
            quantizer: None, // honors weight_quant (GPTQ by default)
            smooth: false,
        });
        reg.register(MethodSpec {
            name: "OmniQuant".into(),
            aliases: vec!["omniquant".into(), "omni".into()],
            rotation: Arc::new(NoRotation),
            quantizer: Some(Arc::new(OmniQuantQuantizer)),
            smooth: false,
        });
        reg.register(MethodSpec {
            name: "QuaRot".into(),
            aliases: vec!["quarot".into()],
            rotation: Arc::new(RandomHadamard),
            quantizer: None,
            smooth: false,
        });
        reg.register(MethodSpec {
            name: "SpinQuant-sim".into(),
            aliases: vec!["spinquant".into(), "spin".into()],
            rotation: Arc::new(SpinCayley),
            quantizer: None,
            smooth: false,
        });
        reg.register(MethodSpec {
            name: "OSTQuant-sim".into(),
            aliases: vec!["ostquant".into(), "ost".into()],
            rotation: Arc::new(SpinCayley),
            quantizer: None,
            smooth: true,
        });
        reg.register(MethodSpec {
            name: "DartQuant".into(),
            aliases: vec!["dartquant".into(), "dart".into()],
            rotation: Arc::new(DartCalibrated),
            quantizer: None,
            smooth: false,
        });
        reg
    }

    /// Add a spec; an existing spec with the same (case-insensitive) name
    /// is replaced, so callers can override built-ins.
    pub fn register(&mut self, spec: MethodSpec) -> &mut MethodRegistry {
        let key = spec.name.to_ascii_lowercase();
        self.specs.retain(|s| s.name.to_ascii_lowercase() != key);
        self.specs.push(spec);
        self
    }

    /// Look a method up by display name or alias (case-insensitive).
    /// Display names win over aliases, so a spec registered under a name
    /// that collides with an older spec's alias (e.g. a custom "Dart"
    /// overriding the builtin DartQuant's alias) is still reachable.
    pub fn resolve(&self, name: &str) -> Result<&MethodSpec> {
        let key = name.to_ascii_lowercase();
        self.specs
            .iter()
            .find(|s| s.name.to_ascii_lowercase() == key)
            .or_else(|| self.specs.iter().find(|s| s.aliases.iter().any(|a| *a == key)))
            .with_context(|| {
                format!("unknown method {name:?} (registered: {})", self.names().join(", "))
            })
    }

    /// Registered display names, in registration order.
    pub fn names(&self) -> Vec<&str> {
        self.specs.iter().map(|s| s.name.as_str()).collect()
    }

    /// Every registered spec, in registration order.
    pub fn specs(&self) -> &[MethodSpec] {
        &self.specs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_registry_has_all_eight_methods() {
        let reg = MethodRegistry::builtin();
        assert_eq!(reg.names().len(), super::super::Method::ALL.len());
        for m in super::super::Method::ALL {
            assert_eq!(reg.resolve(m.name()).unwrap().name, m.name());
        }
        assert!(reg.resolve("awq").is_err());
    }

    #[test]
    fn aliases_resolve_case_insensitively() {
        let reg = MethodRegistry::builtin();
        assert_eq!(reg.resolve("DART").unwrap().name, "DartQuant");
        assert_eq!(reg.resolve("Smooth").unwrap().name, "SmoothQuant");
        assert_eq!(reg.resolve("spinquant-SIM").unwrap().name, "SpinQuant-sim");
    }

    #[test]
    fn register_replaces_same_name() {
        let mut reg = MethodRegistry::builtin();
        let n = reg.names().len();
        reg.register(MethodSpec {
            name: "rtn".into(), // replaces the builtin RTN, case-insensitive
            aliases: vec![],
            rotation: Arc::new(RandomOrthogonal),
            quantizer: None,
            smooth: false,
        });
        assert_eq!(reg.names().len(), n);
        assert_eq!(reg.resolve("rtn").unwrap().rotation.name(), "random-orthogonal");
    }

    #[test]
    fn display_name_beats_older_alias() {
        // A custom spec whose *name* collides with a builtin's *alias*
        // must win resolution for that key (names beat aliases).
        let mut reg = MethodRegistry::builtin();
        reg.register(MethodSpec {
            name: "Dart".into(), // collides with DartQuant's "dart" alias
            aliases: vec![],
            rotation: Arc::new(RandomOrthogonal),
            quantizer: None,
            smooth: false,
        });
        assert_eq!(reg.resolve("dart").unwrap().rotation.name(), "random-orthogonal");
        assert_eq!(reg.resolve("dartquant").unwrap().name, "DartQuant");
    }

    #[test]
    fn mixed_sites_cover_every_linear() {
        let sites = mixed_sites(2);
        assert_eq!(sites.len(), 14);
        assert!(sites.iter().any(|(t, s)| t == "l1.wu" && s == "l1.wg"));
    }
}
