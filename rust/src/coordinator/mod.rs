//! L3 coordinator — the paper's systems contribution as a runnable
//! pipeline:
//!
//! ```text
//! capture → token-sample → calibrate R1 (1 job) + R2 (L parallel jobs)
//!        → fuse rotations → quantize weights (RTN/GPTQ) → report
//! ```
//!
//! The pipeline is an open method space: rotation strategies and weight
//! quantizers are trait objects ([`RotationStrategy`], [`WeightQuantizer`])
//! composed by name through the [`MethodRegistry`] and executed in
//! discrete, individually-timed stages by the [`Pipeline`] builder
//! (`stages`). Progress flows through typed [`PipelineEvent`]s to a
//! [`PipelineObserver`]; runs summarize to JSON via `report`.
//!
//! Calibration decomposes into independent per-layer jobs executed by the
//! [`scheduler::Scheduler`] on worker threads (each worker owns a PJRT
//! runtime; the xla client is thread-bound) under a
//! [`budget::MemoryGate`]. Per-job seeding and ordered event delivery
//! make parallel runs bit-identical to serial ones — the determinism
//! contract in `docs/CONCURRENCY.md`. The "3090 mode" budget admits
//! DartQuant's per-rotation jobs but rejects the end-to-end fine-tuning
//! job — reproducing Table 3's resource story.
//!
//! [`Method`] survives as a thin compatibility shim over registry lookups,
//! and [`run_pipeline`] as a thin wrapper over the builder.

pub mod budget;
pub mod capture;
pub mod registry;
pub mod report;
pub mod scheduler;
pub mod stages;

pub use budget::{MemoryGate, OverBudget, OwnedLease};
pub use capture::{
    capture_pools, capture_pools_native, capture_pools_streamed, CalibrationPools,
};
pub use registry::{
    act_absmax, AtomQuantizer, DartCalibrated, GptqQuantizer, MethodRegistry, MethodSpec,
    NoRotation, OmniQuantQuantizer, QuikQuantizer, RandomHadamard, RandomOrthogonal,
    RotationOutcome, RotationStrategy, RtnQuantizer, SpinCayley, StageContext, WeightQuantizer,
};
pub use report::{
    CollectingObserver, NullObserver, PipelineEvent, PipelineObserver, PipelineRecord,
    PipelineReport, PipelineStats, PrintObserver, Stage,
};
pub use scheduler::{CalibJob, JobSink, Scheduler};
pub use stages::{Pipeline, PipelineBuilder};

use crate::calib::{CalibConfig, SpinConfig};
use crate::data::Dialect;
use crate::model::{ModelConfig, Weights};
use crate::runtime::Runtime;
use anyhow::Result;
use std::path::PathBuf;

/// Quantization method — the rows of Table 2. A compatibility shim: each
/// variant names a [`MethodRegistry::builtin`] spec, and parsing goes
/// through the registry. New methods need only a registry entry, not a
/// variant here.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    Rtn,
    SmoothQuant,
    Gptq,
    /// Learnable weight clipping (OmniQuant-like).
    OmniQuant,
    /// Random-Hadamard rotations (QuaRot).
    QuaRot,
    /// End-to-end Cayley fine-tuning of R1 (SpinQuant-sim).
    SpinQuant,
    /// SpinQuant-sim + SmoothQuant scales (OSTQuant-sim).
    OstQuant,
    /// Whip + QR-Orth rotational distribution calibration (the paper).
    DartQuant,
}

impl Method {
    /// Every built-in method, in Table 2 row order.
    pub const ALL: [Method; 8] = [
        Method::Rtn,
        Method::SmoothQuant,
        Method::Gptq,
        Method::OmniQuant,
        Method::QuaRot,
        Method::SpinQuant,
        Method::OstQuant,
        Method::DartQuant,
    ];

    /// The registry display name of this method.
    pub fn name(&self) -> &'static str {
        match self {
            Method::Rtn => "RTN",
            Method::SmoothQuant => "SmoothQuant",
            Method::Gptq => "GPTQ",
            Method::OmniQuant => "OmniQuant",
            Method::QuaRot => "QuaRot",
            Method::SpinQuant => "SpinQuant-sim",
            Method::OstQuant => "OSTQuant-sim",
            Method::DartQuant => "DartQuant",
        }
    }

    /// Inverse of [`Method::name`] (exact display-name match).
    pub fn from_name(name: &str) -> Option<Method> {
        Method::ALL.into_iter().find(|m| m.name() == name)
    }

    /// Parse a name or alias through the built-in registry.
    pub fn parse(s: &str) -> Result<Method> {
        let registry = MethodRegistry::builtin();
        let spec = registry.resolve(s)?;
        Method::from_name(&spec.name)
            .ok_or_else(|| anyhow::anyhow!("method {:?} has no legacy Method variant", spec.name))
    }

    /// Whether this method produces a rotation set.
    pub fn uses_rotations(&self) -> bool {
        matches!(
            self,
            Method::QuaRot | Method::SpinQuant | Method::OstQuant | Method::DartQuant
        )
    }
}

/// How weights are quantized after rotation fusion (the configurable axis
/// for methods whose registry spec doesn't fix a quantizer).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WeightQuant {
    Rtn,
    Gptq,
}

impl WeightQuant {
    /// Lowercase quantizer name (CLI `--wquant` values).
    pub fn name(&self) -> &'static str {
        match self {
            WeightQuant::Rtn => "rtn",
            WeightQuant::Gptq => "gptq",
        }
    }

    /// Parse a CLI `--wquant` value.
    pub fn parse(s: &str) -> Result<WeightQuant> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "rtn" => WeightQuant::Rtn,
            "gptq" => WeightQuant::Gptq,
            other => anyhow::bail!("unknown weight quantizer {other:?} (rtn|gptq)"),
        })
    }
}

/// Full pipeline configuration.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    /// The method to run (legacy axis; the builder's `.method()` wins).
    pub method: Method,
    /// Target W-A-KV bit setting.
    pub bits: crate::model::BitSetting,
    /// Weight quantizer for methods whose spec doesn't fix one.
    pub weight_quant: WeightQuant,
    /// Calibration data dialect.
    pub calib_dialect: Dialect,
    /// Calibration sequences (paper: 128).
    pub calib_sequences: usize,
    /// Calibration sequence length in tokens.
    pub calib_seq_len: usize,
    /// Token sampling fraction (paper: 10%).
    pub token_frac: f64,
    /// Rotation-calibration hyper-parameters (per-job seeds derive from
    /// `calib.seed ⊕ job id`).
    pub calib: CalibConfig,
    /// End-to-end Cayley fine-tuning hyper-parameters (SpinQuant-sim).
    pub spin: SpinConfig,
    /// Worker threads for the per-layer calibration scheduler
    /// (`0` = available parallelism, the default).
    pub workers: usize,
    /// Within-layer tensor-parallel shards (CLI `--shards`; 1 = off).
    /// GPTQ/OmniQuant per-layer jobs decompose into per-shard row-range
    /// sub-jobs — same bits, smaller per-job gate charges — and the
    /// packed eval/serving forward shards its linears and attention
    /// (`tensor::shard`, `docs/CONCURRENCY.md`). Any shard count
    /// produces byte-identical reports, weights, and token streams.
    pub shards: usize,
    /// Emit packed low-bit weight storage (`tensor::QMat`) from the
    /// quantize stage instead of dequantized f32 — the true-footprint
    /// serving representation (CLI `--packed`). Applies when the weight
    /// bit width packs (2..=8); the eval path then runs the native
    /// integer forward (packed models cannot feed the f32 artifacts).
    pub packed: bool,
    /// Base seed for capture-stage token sampling.
    pub seed: u64,
    /// Memory budget in bytes for scheduler jobs — rotation calibration
    /// *and* per-layer quantizer jobs (OmniQuant's grid search) charge
    /// against it (None = unlimited; `Some(24 << 20)` = the scaled
    /// single-3090 mode).
    pub memory_budget: Option<u64>,
    /// Out-of-core execution (CLI `--streaming`): spill the weights to an
    /// indexed on-disk artifact and run every stage through
    /// checkout/checkin leases on a `model::WeightStore`, so the store's
    /// peak resident weight bytes are bounded by `resident_budget`
    /// instead of model size. Canonical reports stay byte-identical to
    /// in-memory runs for the native-capable method grid; DartQuant's
    /// streamed runs capture natively rather than through the PJRT
    /// artifact — the determinism contract and its capture-backend
    /// carve-out are in `docs/STREAMING.md`.
    pub streaming: bool,
    /// Resident weight-byte budget for streamed runs (CLI
    /// `--resident-budget`; None = unlimited but still peak-tracked).
    /// Checkouts block while over budget; a checkout that can never fit
    /// fails the run. `model::suggested_resident_budget` gives the
    /// smallest budget every built-in streamed stage fits.
    pub resident_budget: Option<u64>,
    /// Directory for the streamed run's spill artifact (None = the OS
    /// temp dir). The spill file is removed when the run finishes.
    pub stream_dir: Option<PathBuf>,
    /// Where the AOT artifacts live (worker runtimes open this dir).
    pub artifacts_dir: PathBuf,
}

impl PipelineConfig {
    /// The default configuration for `method` at `bits` (32 calibration
    /// sequences, Wiki dialect, GPTQ fallback quantizer, all cores).
    pub fn new(method: Method, bits: crate::model::BitSetting) -> PipelineConfig {
        PipelineConfig {
            method,
            bits,
            weight_quant: WeightQuant::Gptq,
            calib_dialect: Dialect::Wiki,
            calib_sequences: 32,
            calib_seq_len: 256,
            token_frac: 0.1,
            calib: CalibConfig::default(),
            spin: SpinConfig::default(),
            workers: 0, // 0 = available parallelism, resolved by the scheduler
            shards: 1,
            packed: false,
            seed: 0,
            memory_budget: None,
            streaming: false,
            resident_budget: None,
            stream_dir: None,
            artifacts_dir: Runtime::default_dir(),
        }
    }
}

/// Run the full quantization pipeline for one model + method + bits.
///
/// Thin compatibility wrapper: equivalent to
/// `Pipeline::builder(weights).config(cfg.clone()).run(rt)`.
pub fn run_pipeline(
    rt: &Runtime,
    weights: &Weights,
    cfg: &PipelineConfig,
) -> Result<PipelineReport> {
    Pipeline::builder(weights).config(cfg.clone()).run(rt)
}

/// Logical bytes a DartQuant calibration job holds: the sampled pool, the
/// latent + momentum matrices, and the step batch.
pub fn job_bytes(pool: &crate::tensor::Mat) -> u64 {
    let n = pool.cols as u64;
    pool.nbytes() + 3 * n * n * 4 + (crate::calib::CALIB_TOKENS as u64) * n * 4
}

/// Logical bytes the end-to-end fine-tuning job holds: weights + gradient
/// + momentum + R1 state + per-layer backprop activations (batch 8 × seq
/// 256 × dim × 2 sites/layer, f32).
pub fn spin_job_bytes(cfg: &ModelConfig) -> u64 {
    let w = cfg.n_params() as u64 * 4;
    let d = cfg.dim as u64;
    let acts = 8 * 256 * d * 2 * cfg.n_layers as u64 * 4;
    3 * w + 3 * d * d * 4 + acts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parse_roundtrip() {
        for m in Method::ALL {
            let parsed = Method::parse(m.name().split('-').next().unwrap()).unwrap();
            assert_eq!(parsed, m, "{}", m.name());
        }
        assert!(Method::parse("awq").is_err());
    }

    #[test]
    fn method_from_name_inverts_name() {
        for m in Method::ALL {
            assert_eq!(Method::from_name(m.name()), Some(m));
        }
        assert_eq!(Method::from_name("rtn"), None); // exact display names only
    }

    #[test]
    fn weight_quant_parse() {
        assert_eq!(WeightQuant::parse("RTN").unwrap(), WeightQuant::Rtn);
        assert_eq!(WeightQuant::parse("gptq").unwrap(), WeightQuant::Gptq);
        assert!(WeightQuant::parse("awq").is_err());
    }

    #[test]
    fn job_bytes_are_sane() {
        let pool = crate::tensor::Mat::zeros(1000, 256);
        let b = job_bytes(&pool);
        assert!(b > pool.nbytes());
        assert!(b < 100 << 20);
        let cfg = ModelConfig::builtin("llama2-large").unwrap();
        // e2e fine-tuning state must dwarf a calibration job (Table 3's
        // memory gap at the 70B stand-in).
        assert!(spin_job_bytes(&cfg) > 10 * b);
    }

    #[test]
    fn spin_is_rejected_under_3090_budget() {
        // Budget admission happens before any PJRT work, so this tests the
        // gate path without needing artifacts.
        let cfg = ModelConfig::builtin("llama2-large").unwrap();
        let gate = MemoryGate::scaled_3090();
        assert!(gate.admit(spin_job_bytes(&cfg)).is_err());
        let pool = crate::tensor::Mat::zeros(3000, cfg.dim);
        assert!(gate.admit(job_bytes(&pool)).is_ok());
    }
}
