//! L3 coordinator — the paper's systems contribution as a runnable
//! pipeline:
//!
//! ```text
//! capture → token-sample → calibrate R1 (1 job) + R2 (L parallel jobs)
//!        → fuse rotations → quantize weights (RTN/GPTQ) → report
//! ```
//!
//! Calibration jobs run on a worker pool (each worker owns a PJRT runtime;
//! the xla client is thread-bound) under a [`budget::MemoryGate`]. The
//! "3090 mode" budget admits DartQuant's per-rotation jobs but rejects the
//! end-to-end fine-tuning job — reproducing Table 3's resource story.

pub mod budget;
pub mod capture;

pub use budget::{MemoryGate, OverBudget};
pub use capture::{capture_pools, capture_pools_native, CalibrationPools};

use crate::calib::{self, CalibConfig, SpinConfig};
use crate::data::{Corpus, Dialect};
use crate::model::{ModelConfig, TokenBatch, Weights};
use crate::quant::{self, GptqConfig};
use crate::rotation::{self, RotationSet, SmoothStats};
use crate::runtime::{with_thread_runtime, Runtime};
use crate::util::prng::Pcg64;
use crate::util::threadpool::ThreadPool;
use anyhow::{Context, Result};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Quantization method — the rows of Table 2.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    Rtn,
    SmoothQuant,
    Gptq,
    /// Learnable weight clipping (OmniQuant-like).
    OmniQuant,
    /// Random-Hadamard rotations (QuaRot).
    QuaRot,
    /// End-to-end Cayley fine-tuning of R1 (SpinQuant-sim).
    SpinQuant,
    /// SpinQuant-sim + SmoothQuant scales (OSTQuant-sim).
    OstQuant,
    /// Whip + QR-Orth rotational distribution calibration (the paper).
    DartQuant,
}

impl Method {
    pub const ALL: [Method; 8] = [
        Method::Rtn,
        Method::SmoothQuant,
        Method::Gptq,
        Method::OmniQuant,
        Method::QuaRot,
        Method::SpinQuant,
        Method::OstQuant,
        Method::DartQuant,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Method::Rtn => "RTN",
            Method::SmoothQuant => "SmoothQuant",
            Method::Gptq => "GPTQ",
            Method::OmniQuant => "OmniQuant",
            Method::QuaRot => "QuaRot",
            Method::SpinQuant => "SpinQuant-sim",
            Method::OstQuant => "OSTQuant-sim",
            Method::DartQuant => "DartQuant",
        }
    }

    pub fn parse(s: &str) -> Result<Method> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "rtn" => Method::Rtn,
            "smoothquant" | "smooth" => Method::SmoothQuant,
            "gptq" => Method::Gptq,
            "omniquant" | "omni" => Method::OmniQuant,
            "quarot" => Method::QuaRot,
            "spinquant" | "spin" => Method::SpinQuant,
            "ostquant" | "ost" => Method::OstQuant,
            "dartquant" | "dart" => Method::DartQuant,
            other => anyhow::bail!("unknown method {other:?}"),
        })
    }

    pub fn uses_rotations(&self) -> bool {
        matches!(
            self,
            Method::QuaRot | Method::SpinQuant | Method::OstQuant | Method::DartQuant
        )
    }
}

/// How weights are quantized after rotation fusion.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WeightQuant {
    Rtn,
    Gptq,
}

/// Full pipeline configuration.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    pub method: Method,
    pub bits: crate::model::BitSetting,
    pub weight_quant: WeightQuant,
    pub calib_dialect: Dialect,
    /// Calibration sequences (paper: 128).
    pub calib_sequences: usize,
    pub calib_seq_len: usize,
    /// Token sampling fraction (paper: 10%).
    pub token_frac: f64,
    pub calib: CalibConfig,
    pub spin: SpinConfig,
    pub workers: usize,
    pub seed: u64,
    /// Memory budget in bytes for calibration jobs (None = unlimited;
    /// `Some(24 << 20)` = the scaled single-3090 mode).
    pub memory_budget: Option<u64>,
    pub artifacts_dir: PathBuf,
}

impl PipelineConfig {
    pub fn new(method: Method, bits: crate::model::BitSetting) -> PipelineConfig {
        PipelineConfig {
            method,
            bits,
            weight_quant: WeightQuant::Gptq,
            calib_dialect: Dialect::Wiki,
            calib_sequences: 32,
            calib_seq_len: 256,
            token_frac: 0.1,
            calib: CalibConfig::default(),
            spin: SpinConfig::default(),
            workers: ThreadPool::default_parallelism().min(4),
            seed: 0,
            memory_budget: None,
            artifacts_dir: Runtime::default_dir(),
        }
    }
}

/// Timing + memory accounting of one pipeline run (Table 3 / Fig 1 data).
#[derive(Clone, Debug, Default)]
pub struct PipelineStats {
    pub capture_time: Duration,
    pub calibrate_time: Duration,
    pub quantize_time: Duration,
    pub total_time: Duration,
    /// Peak job-resident bytes admitted by the memory gate.
    pub peak_job_bytes: u64,
    /// Calibration loss curves (R1 first, then R2 per layer).
    pub loss_curves: Vec<Vec<f32>>,
}

/// Pipeline output: quantized (dequantized-f32) weights ready for the
/// `fwdq_*` artifacts, plus the rotation set actually applied.
pub struct PipelineReport {
    pub weights: Weights,
    pub rotation: Option<RotationSet>,
    pub stats: PipelineStats,
}

/// Run the full quantization pipeline for one model + method + bits.
pub fn run_pipeline(
    rt: &Runtime,
    weights: &Weights,
    cfg: &PipelineConfig,
) -> Result<PipelineReport> {
    let t_total = Instant::now();
    let model_cfg = weights.cfg.clone();
    let corpus = Corpus::new(cfg.calib_dialect, model_cfg.vocab, 7);
    let calib_seqs = corpus.calib_sequences(cfg.calib_sequences, cfg.calib_seq_len);
    let gate = Arc::new(MemoryGate::new(cfg.memory_budget));
    let mut stats = PipelineStats::default();

    // ---- rotation stage --------------------------------------------------
    let mut rng = Pcg64::new(cfg.seed ^ 0x707);
    let rotation: Option<RotationSet> = match cfg.method {
        Method::Rtn | Method::SmoothQuant | Method::Gptq | Method::OmniQuant => None,
        Method::QuaRot => Some(RotationSet::random_hadamard(
            model_cfg.dim,
            model_cfg.head_dim,
            model_cfg.n_layers,
            &mut rng,
        )),
        Method::SpinQuant | Method::OstQuant => {
            // End-to-end Cayley: ONE job holding the whole model +
            // optimizer + backprop state; charged in full against the gate.
            let t0 = Instant::now();
            let need = spin_job_bytes(&model_cfg);
            let _lease = gate.admit(need).map_err(|e| {
                anyhow::anyhow!("{} cannot run under this memory budget: {e}", cfg.method.name())
            })?;
            let dialect = cfg.calib_dialect;
            let (vocab, seq_len) = (model_cfg.vocab, cfg.calib_seq_len);
            let res = calib::spin_calibrate(rt, weights, &cfg.spin, move |step| {
                let c = Corpus::new(dialect, vocab, 7);
                TokenBatch::new(&c.calib_sequences_at(8, seq_len, step as u64))
            })?;
            stats.loss_curves.push(res.losses.clone());
            stats.calibrate_time += t0.elapsed();
            Some(RotationSet {
                r1: res.r1,
                r2: (0..model_cfg.n_layers)
                    .map(|_| crate::linalg::randomized_hadamard(model_cfg.head_dim, &mut rng))
                    .collect(),
                online_had: true,
            })
        }
        Method::DartQuant => {
            // Capture (data-plane) then R1 + per-layer R2 jobs on workers.
            let t0 = Instant::now();
            let pools = capture_pools(rt, weights, &calib_seqs, cfg.token_frac, cfg.seed)?;
            stats.capture_time = t0.elapsed();

            let t1 = Instant::now();
            let dir = cfg.artifacts_dir.clone();
            let pool = ThreadPool::new(cfg.workers);
            let mut jobs: Vec<(usize, crate::tensor::Mat, CalibConfig)> = Vec::new();
            jobs.push((0, pools.r1_pool.clone(), cfg.calib.clone()));
            for (l, p) in pools.r2_pools.iter().enumerate() {
                let mut c2 = cfg.calib.clone();
                c2.lr = 1e-3; // paper Table 23: R2 uses lr 1e-3
                // R2 jobs always use whip (the ablation objectives are
                // emitted only at the R1 dims; matches the paper, which
                // ablates the R1 objective only).
                c2.objective = crate::calib::Objective::Whip;
                jobs.push((l + 1, p.clone(), c2));
            }
            let gate2 = Arc::clone(&gate);
            let results = pool.map(jobs, move |(id, pool_mat, ccfg)| -> Result<_> {
                let need = job_bytes(&pool_mat);
                let _lease = gate2.admit(need)?;
                let r = with_thread_runtime(&dir, |rt| {
                    calib::calibrate_rotation(rt, &pool_mat, &ccfg)
                })??;
                Ok((id, r))
            });
            let mut r1 = None;
            let mut r2: Vec<Option<crate::tensor::Mat>> = vec![None; model_cfg.n_layers];
            for res in results {
                let (id, r) = res.context("calibration job failed")?;
                stats.loss_curves.push(r.losses.clone());
                if id == 0 {
                    r1 = Some(r.rotation);
                } else {
                    r2[id - 1] = Some(r.rotation);
                }
            }
            stats.calibrate_time = t1.elapsed();
            Some(RotationSet {
                r1: r1.context("missing R1")?,
                r2: r2.into_iter().map(|r| r.unwrap()).collect(),
                online_had: true,
            })
        }
    };

    // ---- fuse + smooth -----------------------------------------------------
    let mut working = match &rotation {
        Some(rot) => rotation::fuse(weights, rot),
        None => weights.clone(),
    };
    if matches!(cfg.method, Method::SmoothQuant | Method::OstQuant) && !model_cfg.is_moe() {
        let stats_seqs = corpus.calib_sequences(4.min(cfg.calib_sequences), cfg.calib_seq_len);
        let sstats = SmoothStats::capture(&working, &stats_seqs);
        working = rotation::smooth_scales(&working, &sstats, 0.5);
    }

    // ---- weight quantization -------------------------------------------------
    let t2 = Instant::now();
    let quantized = if cfg.bits.w >= 16 {
        working
    } else {
        match (cfg.method, cfg.weight_quant) {
            (Method::OmniQuant, _) => quant::omniquant_quantize_model(&working, cfg.bits.w),
            (Method::Rtn | Method::SmoothQuant, _) | (_, WeightQuant::Rtn) => {
                quant::rtn_quantize_model(&working, cfg.bits.w)
            }
            (_, WeightQuant::Gptq) => {
                let gseqs = corpus.calib_sequences(8.min(cfg.calib_sequences), cfg.calib_seq_len);
                quant::gptq_quantize_model(
                    &working,
                    &gseqs,
                    GptqConfig { bits: cfg.bits.w, damp: 0.01 },
                )
            }
        }
    };
    stats.quantize_time = t2.elapsed();
    stats.total_time = t_total.elapsed();
    stats.peak_job_bytes = gate.peak_bytes();

    Ok(PipelineReport { weights: quantized, rotation, stats })
}

/// Logical bytes a DartQuant calibration job holds: the sampled pool, the
/// latent + momentum matrices, and the step batch.
pub fn job_bytes(pool: &crate::tensor::Mat) -> u64 {
    let n = pool.cols as u64;
    pool.nbytes() + 3 * n * n * 4 + (calib::CALIB_TOKENS as u64) * n * 4
}

/// Logical bytes the end-to-end fine-tuning job holds: weights + gradient
/// + momentum + R1 state + per-layer backprop activations (batch 8 × seq
/// 256 × dim × 2 sites/layer, f32).
pub fn spin_job_bytes(cfg: &ModelConfig) -> u64 {
    let w = cfg.n_params() as u64 * 4;
    let d = cfg.dim as u64;
    let acts = 8 * 256 * d * 2 * cfg.n_layers as u64 * 4;
    3 * w + 3 * d * d * 4 + acts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parse_roundtrip() {
        for m in Method::ALL {
            let parsed = Method::parse(m.name().split('-').next().unwrap()).unwrap();
            assert_eq!(parsed, m, "{}", m.name());
        }
        assert!(Method::parse("awq").is_err());
    }

    #[test]
    fn job_bytes_are_sane() {
        let pool = crate::tensor::Mat::zeros(1000, 256);
        let b = job_bytes(&pool);
        assert!(b > pool.nbytes());
        assert!(b < 100 << 20);
        let cfg = ModelConfig::builtin("llama2-large").unwrap();
        // e2e fine-tuning state must dwarf a calibration job (Table 3's
        // memory gap at the 70B stand-in).
        assert!(spin_job_bytes(&cfg) > 10 * b);
    }

    #[test]
    fn spin_is_rejected_under_3090_budget() {
        // Budget admission happens before any PJRT work, so this tests the
        // gate path without needing artifacts.
        let cfg = ModelConfig::builtin("llama2-large").unwrap();
        let gate = MemoryGate::scaled_3090();
        assert!(gate.admit(spin_job_bytes(&cfg)).is_err());
        let pool = crate::tensor::Mat::zeros(3000, cfg.dim);
        assert!(gate.admit(job_bytes(&pool)).is_ok());
    }
}
