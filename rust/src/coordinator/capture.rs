//! Activation capture + token sampling — the data-plane of Algorithm 1.
//!
//! `X ← LLM(S); X ← token_sampling(X)`: run the capture artifact over the
//! calibration sequences, then subsample token rows (paper: 10%) per site
//! to build the rotation-calibration pools:
//!
//! * R1 pool — post-RMSNorm hidden states pooled over all 2L sites,
//! * R2 pools — value-projection outputs per layer, reshaped to per-head
//!   rows (the R2 rotation acts on head_dim).

use crate::model::{artifact_io, TokenBatch, Weights};
use crate::runtime::Runtime;
use crate::tensor::Mat;
use crate::util::prng::Pcg64;
use anyhow::Result;

/// Calibration pools for every rotation site.
pub struct CalibrationPools {
    /// (rows, dim) — pooled R1-site activations.
    pub r1_pool: Mat,
    /// Per layer: (rows, head_dim) — per-head value rows.
    pub r2_pools: Vec<Mat>,
    /// Total tokens captured before sampling.
    pub captured_tokens: usize,
}

impl CalibrationPools {
    pub fn nbytes(&self) -> u64 {
        self.r1_pool.nbytes() + self.r2_pools.iter().map(|m| m.nbytes()).sum::<u64>()
    }
}

/// Row-concatenate captured parts into one pool matrix.
fn concat(parts: &[Mat]) -> Mat {
    let cols = parts[0].cols;
    let rows = parts.iter().map(|p| p.rows).sum();
    let mut out = Mat::zeros(rows, cols);
    let mut at = 0;
    for p in parts {
        out.data[at * cols..(at + p.rows) * cols].copy_from_slice(&p.data);
        at += p.rows;
    }
    out
}

/// Capture pools via the PJRT `capture_{cfg}` artifact.
///
/// `sequences` are split into artifact-sized (batch=8) chunks; `frac` is
/// the token sampling fraction (the paper's 10%).
pub fn capture_pools(
    rt: &Runtime,
    weights: &Weights,
    sequences: &[Vec<i32>],
    frac: f64,
    seed: u64,
) -> Result<CalibrationPools> {
    let cfg = &weights.cfg;
    let mut rng = Pcg64::new(seed ^ 0xca9_u64);
    let mut r1_parts: Vec<Mat> = Vec::new();
    let mut r2_parts: Vec<Vec<Mat>> = vec![Vec::new(); cfg.n_layers];
    let mut captured = 0usize;

    const ART_BATCH: usize = 8;
    for chunk in sequences.chunks(ART_BATCH) {
        // Pad the last chunk to the artifact batch (extra rows are real
        // model inputs; their samples are harmless duplicates).
        let mut seqs = chunk.to_vec();
        while seqs.len() < ART_BATCH {
            seqs.push(chunk[seqs.len() % chunk.len()].clone());
        }
        let toks = TokenBatch::new(&seqs);
        let sites = artifact_io::run_capture(rt, weights, &toks)?;
        captured += toks.batch * toks.seq;
        for x in &sites.x_sites {
            let keep = ((x.rows as f64 * frac).ceil() as usize).max(16).min(x.rows);
            let idx = rng.sample_indices(x.rows, keep);
            r1_parts.push(x.gather_rows(&idx));
        }
        for (l, v) in sites.v_sites.iter().enumerate() {
            // Reshape (rows, kv_dim) into per-head (rows·n_kv, head_dim).
            let hd = cfg.head_dim;
            let heads = cfg.n_kv_heads;
            let keep = ((v.rows as f64 * frac).ceil() as usize).max(16).min(v.rows);
            let idx = rng.sample_indices(v.rows, keep);
            let sub = v.gather_rows(&idx);
            let mut rows = Mat::zeros(sub.rows * heads, hd);
            for i in 0..sub.rows {
                for h in 0..heads {
                    let dst = rows.row_mut(i * heads + h);
                    dst.copy_from_slice(&sub.row(i)[h * hd..(h + 1) * hd]);
                }
            }
            r2_parts[l].push(rows);
        }
    }

    Ok(CalibrationPools {
        r1_pool: concat(&r1_parts),
        r2_pools: r2_parts.iter().map(|p| concat(p)).collect(),
        captured_tokens: captured,
    })
}

/// Native-forward fallback (no artifacts needed): capture through hooks.
pub fn capture_pools_native(
    weights: &Weights,
    sequences: &[Vec<i32>],
    frac: f64,
    seed: u64,
) -> CalibrationPools {
    use crate::model::{forward_one, CaptureHook, FwdOptions};
    struct Hook<'a> {
        rng: &'a mut Pcg64,
        frac: f64,
        hd: usize,
        heads: usize,
        r1: Vec<Mat>,
        r2: Vec<Vec<Mat>>,
    }
    impl CaptureHook for Hook<'_> {
        fn on_x_site(&mut self, _site: usize, h: &Mat) {
            let keep = ((h.rows as f64 * self.frac).ceil() as usize).max(4).min(h.rows);
            let idx = self.rng.sample_indices(h.rows, keep);
            self.r1.push(h.gather_rows(&idx));
        }
        fn on_v_site(&mut self, layer: usize, v: &Mat) {
            let keep = ((v.rows as f64 * self.frac).ceil() as usize).max(4).min(v.rows);
            let idx = self.rng.sample_indices(v.rows, keep);
            let sub = v.gather_rows(&idx);
            let mut rows = Mat::zeros(sub.rows * self.heads, self.hd);
            for i in 0..sub.rows {
                for h in 0..self.heads {
                    rows.row_mut(i * self.heads + h)
                        .copy_from_slice(&sub.row(i)[h * self.hd..(h + 1) * self.hd]);
                }
            }
            self.r2[layer].push(rows);
        }
    }
    let cfg = &weights.cfg;
    let mut rng = Pcg64::new(seed ^ 0xca9_u64);
    let mut hook = Hook {
        rng: &mut rng,
        frac,
        hd: cfg.head_dim,
        heads: cfg.n_kv_heads,
        r1: Vec::new(),
        r2: vec![Vec::new(); cfg.n_layers],
    };
    let mut captured = 0;
    for seq in sequences {
        forward_one(weights, seq, FwdOptions::FP, &mut hook);
        captured += seq.len();
    }
    CalibrationPools {
        r1_pool: concat(&hook.r1),
        r2_pools: hook.r2.iter().map(|p| concat(p)).collect(),
        captured_tokens: captured,
    }
}

/// Streamed native capture over a `model::WeightStore` (no artifacts,
/// weight residency bounded to one layer): the layer-at-a-time forward
/// `model::stream_blocks` feeds the same site hooks as
/// [`capture_pools_native`]. The traversal is layer-major while the
/// in-memory captures are sequence-major and draw sampling indices from
/// one sequential PRNG, so the streamed sampler derives an independent
/// seed per (site, sequence) instead — pools are deterministic for a
/// given seed at any budget, with the same geometry as the in-memory
/// captures; the sampled row *subsets* differ (`docs/STREAMING.md`
/// documents the capture-backend caveat).
pub fn capture_pools_streamed(
    store: &crate::model::WeightStore,
    sequences: &[Vec<i32>],
    frac: f64,
    seed: u64,
) -> Result<CalibrationPools> {
    use crate::model::{stream_blocks, CaptureHook, FwdOptions};
    fn site_rng(seed: u64, kind: u64, site: u64, seq: u64) -> Pcg64 {
        Pcg64::new(seed ^ 0xca9 ^ (kind << 56) ^ (site << 32) ^ seq)
    }
    struct Hook {
        seed: u64,
        frac: f64,
        hd: usize,
        heads: usize,
        /// Per-site call counts: the n-th call for a site is sequence n
        /// (within a layer, `stream_blocks` visits sequences in order).
        seen_x: Vec<usize>,
        seen_v: Vec<usize>,
        r1: Vec<Mat>,
        r2: Vec<Vec<Mat>>,
    }
    impl CaptureHook for Hook {
        fn on_x_site(&mut self, site: usize, h: &Mat) {
            let seq = self.seen_x[site];
            self.seen_x[site] += 1;
            let mut rng = site_rng(self.seed, 1, site as u64, seq as u64);
            let keep = ((h.rows as f64 * self.frac).ceil() as usize).max(4).min(h.rows);
            let idx = rng.sample_indices(h.rows, keep);
            self.r1.push(h.gather_rows(&idx));
        }
        fn on_v_site(&mut self, layer: usize, v: &Mat) {
            let seq = self.seen_v[layer];
            self.seen_v[layer] += 1;
            let mut rng = site_rng(self.seed, 2, layer as u64, seq as u64);
            let keep = ((v.rows as f64 * self.frac).ceil() as usize).max(4).min(v.rows);
            let idx = rng.sample_indices(v.rows, keep);
            let sub = v.gather_rows(&idx);
            let mut rows = Mat::zeros(sub.rows * self.heads, self.hd);
            for i in 0..sub.rows {
                for h in 0..self.heads {
                    rows.row_mut(i * self.heads + h)
                        .copy_from_slice(&sub.row(i)[h * self.hd..(h + 1) * self.hd]);
                }
            }
            self.r2[layer].push(rows);
        }
    }
    let cfg = store.cfg().clone();
    let mut hook = Hook {
        seed,
        frac,
        hd: cfg.head_dim,
        heads: cfg.n_kv_heads,
        seen_x: vec![0; 2 * cfg.n_layers],
        seen_v: vec![0; cfg.n_layers],
        r1: Vec::new(),
        r2: vec![Vec::new(); cfg.n_layers],
    };
    stream_blocks(store, sequences, FwdOptions::FP, &mut hook, |_, _, _| Ok(()))?;
    Ok(CalibrationPools {
        r1_pool: concat(&hook.r1),
        r2_pools: hook.r2.iter().map(|p| concat(p)).collect(),
        captured_tokens: sequences.iter().map(|s| s.len()).sum(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Corpus, Dialect};
    use crate::model::ModelConfig;

    #[test]
    fn native_capture_geometry_and_sampling() {
        let cfg = ModelConfig::builtin("llama2-tiny").unwrap();
        let corpus = Corpus::new(Dialect::Wiki, cfg.vocab, 7);
        let w = Weights::default_grammar(&cfg, 1, corpus.successor()).unwrap();
        let seqs = corpus.calib_sequences(2, 40);
        let pools = capture_pools_native(&w, &seqs, 0.1, 3);
        assert_eq!(pools.r1_pool.cols, cfg.dim);
        assert_eq!(pools.r2_pools.len(), cfg.n_layers);
        assert_eq!(pools.r2_pools[0].cols, cfg.head_dim);
        assert_eq!(pools.captured_tokens, 80);
        // ~10% sampling: 2 seqs × 40 tokens × 2L sites × 10% = 64 rows min-capped
        let expect = 2 * 40 * 2 * cfg.n_layers / 10;
        assert!(pools.r1_pool.rows >= expect / 2 && pools.r1_pool.rows <= expect * 3);
    }

    #[test]
    fn deterministic_by_seed() {
        let cfg = ModelConfig::builtin("llama2-tiny").unwrap();
        let corpus = Corpus::new(Dialect::Wiki, cfg.vocab, 7);
        let w = Weights::default_grammar(&cfg, 1, corpus.successor()).unwrap();
        let seqs = corpus.calib_sequences(1, 32);
        let a = capture_pools_native(&w, &seqs, 0.2, 5);
        let b = capture_pools_native(&w, &seqs, 0.2, 5);
        assert_eq!(a.r1_pool.data, b.r1_pool.data);
    }

    #[test]
    fn streamed_capture_matches_native_geometry_and_is_deterministic() {
        use crate::model::{suggested_resident_budget, WeightStore};
        let cfg = ModelConfig::builtin("llama2-tiny").unwrap();
        let corpus = Corpus::new(Dialect::Wiki, cfg.vocab, 7);
        let w = Weights::default_grammar(&cfg, 1, corpus.successor()).unwrap();
        let seqs = corpus.calib_sequences(2, 40);
        let path =
            std::env::temp_dir().join(format!("dq-capture-{}.dartq", std::process::id()));
        let store =
            WeightStore::create(&path, &w, Some(suggested_resident_budget(&cfg))).unwrap();
        let a = capture_pools_streamed(&store, &seqs, 0.1, 3).unwrap();
        let b = capture_pools_streamed(&store, &seqs, 0.1, 3).unwrap();
        assert_eq!(a.r1_pool.data, b.r1_pool.data, "streamed capture must be deterministic");
        assert_eq!(a.captured_tokens, 80);
        assert_eq!(a.r1_pool.cols, cfg.dim);
        assert_eq!(a.r2_pools.len(), cfg.n_layers);
        assert_eq!(a.r2_pools[0].cols, cfg.head_dim);
        // Same keep-count formula per site event ⇒ same pool geometry as
        // the in-memory native capture (the sampled subsets differ).
        let native = capture_pools_native(&w, &seqs, 0.1, 3);
        assert_eq!(a.r1_pool.rows, native.r1_pool.rows);
        assert_eq!(a.r2_pools[1].rows, native.r2_pools[1].rows);
        assert!(store.peak_resident_bytes() <= suggested_resident_budget(&cfg));
        std::fs::remove_file(path).ok();
    }
}
