//! The staged pipeline executor and its builder:
//!
//! ```text
//! Pipeline::builder(&weights)
//!     .method("dartquant")?          // or .rotation(...) / .quantizer(...)
//!     .bits(BitSetting::W4A4)
//!     .budget(Some(24 << 20))
//!     .observer(obs)
//!     .run(&rt)?                     // or .run_native() (no artifacts)
//! ```
//!
//! Four discrete, individually-timed stages — capture → calibrate →
//! fuse/smooth → quantize — each bracketed by [`PipelineEvent`] stage
//! events on the observer hook.

use super::budget::MemoryGate;
use super::registry::{
    GptqQuantizer, MethodRegistry, MethodSpec, RotationStrategy, RtnQuantizer, StageContext,
    WeightQuantizer,
};
use super::report::{PipelineEvent, PipelineObserver, PipelineReport, PipelineStats, Stage};
use super::report::NullObserver;
use super::{Method, PipelineConfig, WeightQuant};
use crate::data::Corpus;
use crate::model::{BitSetting, WeightStore, Weights};
use crate::rotation::{self, SmoothStats};
use crate::runtime::Runtime;
use anyhow::Result;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

/// Namespace for [`Pipeline::builder`].
pub struct Pipeline;

impl Pipeline {
    /// Start building a pipeline run over `weights`.
    ///
    /// The builder composes a method (by registry name or explicit
    /// strategy/quantizer), bit setting, memory budget, worker count and
    /// observer, then executes via [`PipelineBuilder::run`] (PJRT) or
    /// [`PipelineBuilder::run_native`]. Out-of-tree strategies register a
    /// [`MethodSpec`] and run through the same stages — no coordinator
    /// edits:
    ///
    /// ```no_run
    /// use dartquant::coordinator::{
    ///     CalibrationPools, MethodRegistry, MethodSpec, Pipeline, RotationOutcome,
    ///     RotationStrategy, RtnQuantizer, StageContext,
    /// };
    /// use dartquant::model::{BitSetting, ModelConfig, Weights};
    /// use dartquant::rotation::RotationSet;
    /// use std::sync::Arc;
    ///
    /// /// An out-of-tree strategy: identity rotations.
    /// struct NullRotation;
    ///
    /// impl RotationStrategy for NullRotation {
    ///     fn name(&self) -> &str {
    ///         "null-rotation"
    ///     }
    ///     fn calibrate(
    ///         &self,
    ///         ctx: &StageContext,
    ///         _pools: Option<&CalibrationPools>,
    ///     ) -> anyhow::Result<RotationOutcome> {
    ///         let cfg = &ctx.weights.cfg;
    ///         Ok(RotationOutcome::some(RotationSet::identity(
    ///             cfg.dim,
    ///             cfg.head_dim,
    ///             cfg.n_layers,
    ///         )))
    ///     }
    /// }
    ///
    /// fn main() -> anyhow::Result<()> {
    ///     let cfg = ModelConfig::builtin("llama2-tiny")?;
    ///     let weights = Weights::default_synthetic(&cfg, 1);
    ///     let mut registry = MethodRegistry::builtin();
    ///     registry.register(MethodSpec {
    ///         name: "NullQuant".into(),
    ///         aliases: vec!["null".into()],
    ///         rotation: Arc::new(NullRotation),
    ///         quantizer: Some(Arc::new(RtnQuantizer)),
    ///         smooth: false,
    ///     });
    ///     let report = Pipeline::builder(&weights)
    ///         .method_in(&registry, "null")?
    ///         .bits(BitSetting::W4A4)
    ///         .workers(4) // per-layer calibration jobs fan out on 4 threads
    ///         .run_native()?;
    ///     assert_eq!(report.method, "NullQuant");
    ///     Ok(())
    /// }
    /// ```
    pub fn builder(weights: &Weights) -> PipelineBuilder<'_> {
        PipelineBuilder {
            weights,
            cfg: PipelineConfig::new(Method::DartQuant, BitSetting::W4A4),
            spec: None,
            rotation: None,
            quantizer: None,
            smooth: None,
            method_label: None,
            observer: Arc::new(NullObserver),
        }
    }
}

/// Staged builder over the method space. Each axis resolves with a fixed
/// precedence, independent of call order: explicit `.rotation()` /
/// `.quantizer()` / `.smooth()` win, then the spec chosen by `.method()`,
/// then the built-in registry entry for `PipelineConfig::method` (so
/// legacy `run_pipeline(rt, weights, cfg)` callers run unchanged), with
/// `PipelineConfig::weight_quant` as the quantizer fallback.
pub struct PipelineBuilder<'w> {
    weights: &'w Weights,
    cfg: PipelineConfig,
    spec: Option<MethodSpec>,
    rotation: Option<Arc<dyn RotationStrategy>>,
    quantizer: Option<Arc<dyn WeightQuantizer>>,
    smooth: Option<bool>,
    method_label: Option<String>,
    observer: Arc<dyn PipelineObserver>,
}

impl<'w> PipelineBuilder<'w> {
    /// Resolve a method by name from the built-in registry.
    pub fn method(self, name: &str) -> Result<PipelineBuilder<'w>> {
        self.method_in(&MethodRegistry::builtin(), name)
    }

    /// Resolve a method by name from a caller-supplied registry — the
    /// extension point for out-of-tree strategies. Does not clobber axes
    /// already pinned with `.rotation()` / `.quantizer()` / `.smooth()`.
    pub fn method_in(mut self, registry: &MethodRegistry, name: &str) -> Result<PipelineBuilder<'w>> {
        let spec = registry.resolve(name)?;
        if let Some(m) = Method::from_name(&spec.name) {
            self.cfg.method = m; // keep the legacy config field in sync
        }
        self.method_label = Some(spec.name.clone());
        self.spec = Some(spec.clone());
        Ok(self)
    }

    /// Plug a rotation strategy in directly (no registry entry needed).
    pub fn rotation(mut self, strategy: Arc<dyn RotationStrategy>) -> PipelineBuilder<'w> {
        self.method_label.get_or_insert_with(|| strategy.name().to_string());
        self.rotation = Some(strategy);
        self
    }

    /// Plug a weight quantizer in directly (no registry entry needed).
    pub fn quantizer(mut self, quantizer: Arc<dyn WeightQuantizer>) -> PipelineBuilder<'w> {
        self.quantizer = Some(quantizer);
        self
    }

    /// Apply SmoothQuant scaling in the fuse stage.
    pub fn smooth(mut self, on: bool) -> PipelineBuilder<'w> {
        self.smooth = Some(on);
        self
    }

    /// The W-A-KV bit setting the pipeline quantizes to.
    pub fn bits(mut self, bits: BitSetting) -> PipelineBuilder<'w> {
        self.cfg.bits = bits;
        self
    }

    /// Memory budget in bytes for calibration jobs (None = unlimited).
    pub fn budget(mut self, bytes: Option<u64>) -> PipelineBuilder<'w> {
        self.cfg.memory_budget = bytes;
        self
    }

    /// Out-of-core streamed execution (CLI `--streaming`): spill the
    /// weights to an indexed on-disk artifact and run capture →
    /// calibrate → fuse → quantize through `model::WeightStore`
    /// checkout/checkin leases, so the store's peak resident weight
    /// bytes are bounded by [`PipelineBuilder::resident_budget`] rather
    /// than model size. For the native-capable method grid the canonical
    /// report is byte-identical to the in-memory run's; the determinism
    /// contract — including DartQuant's capture-backend carve-out — is
    /// in `docs/STREAMING.md`.
    pub fn streaming(mut self, on: bool) -> PipelineBuilder<'w> {
        self.cfg.streaming = on;
        self
    }

    /// Resident weight-byte budget for streamed runs (None = unlimited,
    /// still peak-tracked; CLI `--resident-budget`). Checkouts block
    /// while over budget; one that can never fit fails the run.
    /// `model::suggested_resident_budget` gives the smallest budget
    /// every built-in streamed stage fits.
    pub fn resident_budget(mut self, bytes: Option<u64>) -> PipelineBuilder<'w> {
        self.cfg.resident_budget = bytes;
        self
    }

    /// Emit packed low-bit weight storage (`tensor::QMat`) from the
    /// quantize stage instead of dequantized f32 — the true-footprint
    /// serving representation (CLI `--packed`). The report's
    /// `model_bytes`/`compression_ratio` then account real codes+scales
    /// bytes, and eval runs the native integer forward.
    pub fn packed(mut self, on: bool) -> PipelineBuilder<'w> {
        self.cfg.packed = on;
        self
    }

    /// Worker threads for the per-layer calibration scheduler
    /// (`0` = the machine's available parallelism). The determinism
    /// contract guarantees bit-identical reports at any setting; see
    /// `docs/CONCURRENCY.md`.
    pub fn workers(mut self, n: usize) -> PipelineBuilder<'w> {
        self.cfg.workers = n;
        self
    }

    /// Within-layer tensor-parallel shards (1 = off). GPTQ/OmniQuant
    /// per-layer jobs split into per-shard row-range sub-jobs with
    /// proportionally smaller gate charges; results are byte-identical
    /// at any shard count (`docs/CONCURRENCY.md`).
    pub fn shards(mut self, n: usize) -> PipelineBuilder<'w> {
        self.cfg.shards = n.max(1);
        self
    }

    /// Receive typed [`PipelineEvent`]s during the run (default: none).
    pub fn observer(mut self, observer: Arc<dyn PipelineObserver>) -> PipelineBuilder<'w> {
        self.observer = observer;
        self
    }

    /// Replace the whole config (method/bits/calibration knobs). Unpinned
    /// axes re-resolve from the new `cfg.method` unless a `.method()` call
    /// already chose a spec.
    pub fn config(mut self, cfg: PipelineConfig) -> PipelineBuilder<'w> {
        self.cfg = cfg;
        self
    }

    /// Tweak individual config knobs in place.
    pub fn configure(mut self, f: impl FnOnce(&mut PipelineConfig)) -> PipelineBuilder<'w> {
        f(&mut self.cfg);
        self
    }

    /// Run with the PJRT runtime (full artifact-backed pipeline).
    pub fn run(self, rt: &Runtime) -> Result<PipelineReport> {
        self.execute(Some(rt))
    }

    /// Run without a PJRT runtime. Strategies and quantizers that need
    /// artifacts return a contextful error; native-capable ones (random
    /// rotations, RTN/GPTQ/OmniQuant/mixed quantizers, smoothing, fusion)
    /// run end-to-end — which is what the no-artifact tests exercise.
    pub fn run_native(self) -> Result<PipelineReport> {
        self.execute(None)
    }

    fn execute(self, rt: Option<&Runtime>) -> Result<PipelineReport> {
        let PipelineBuilder {
            weights,
            cfg,
            spec,
            rotation,
            quantizer,
            smooth,
            method_label,
            observer,
        } = self;
        // Axis precedence: explicit setter → .method() spec → builtin spec
        // for cfg.method → (quantizer only) cfg.weight_quant.
        let spec = match spec {
            Some(s) => s,
            None => MethodRegistry::builtin().resolve(cfg.method.name())?.clone(),
        };
        let rotation = rotation.unwrap_or_else(|| Arc::clone(&spec.rotation));
        let smooth = smooth.unwrap_or(spec.smooth);
        let quantizer = quantizer.or_else(|| spec.quantizer.clone()).unwrap_or_else(|| {
            match cfg.weight_quant {
                WeightQuant::Rtn => Arc::new(RtnQuantizer) as Arc<dyn WeightQuantizer>,
                WeightQuant::Gptq => Arc::new(GptqQuantizer::default()),
            }
        });
        let method_label = method_label.unwrap_or_else(|| spec.name.clone());

        // Packed checkpoints (now persisted natively) enter the pipeline
        // as their dense dequantization — bit-identical to what loading
        // a pre-streaming checkpoint produced, and what the dense-only
        // stages (fuse, capture, re-quantization) require.
        let dense_input;
        let weights = if weights.has_packed() {
            dense_input = weights.to_dense();
            &dense_input
        } else {
            weights
        };

        let t_total = Instant::now();
        let model_cfg = weights.cfg.clone();
        let corpus = Corpus::new(cfg.calib_dialect, model_cfg.vocab, 7);
        let gate = Arc::new(MemoryGate::new(cfg.memory_budget));
        let mut stats = PipelineStats::default();
        let ctx = StageContext {
            rt,
            cfg: &cfg,
            weights,
            corpus: &corpus,
            gate: Arc::clone(&gate),
            observer: Arc::clone(&observer),
        };
        let stage = |s: Stage| observer.on_event(&PipelineEvent::StageStarted { stage: s });
        let stage_done = |s: Stage, t0: Instant| {
            let elapsed = t0.elapsed();
            observer.on_event(&PipelineEvent::StageFinished { stage: s, elapsed });
            elapsed
        };

        // Out-of-core mode: spill the model to an indexed artifact and
        // route every stage's tensor access through WeightStore leases,
        // so peak resident weight bytes stay under the resident budget.
        // The guard removes the spill file when the run ends (Ok or Err).
        let (_spill, store) = if cfg.streaming {
            let path = spill_path(&cfg, &model_cfg.name);
            let guard = SpillGuard(path.clone());
            let store = WeightStore::create(&path, weights, cfg.resident_budget)?;
            (Some(guard), Some(store))
        } else {
            (None, None)
        };

        // ---- capture ------------------------------------------------------
        stage(Stage::Capture);
        let t0 = Instant::now();
        let pools = match &store {
            Some(s) => rotation.capture_streamed(&ctx, s)?,
            None => rotation.capture(&ctx)?,
        };
        stats.capture_time = stage_done(Stage::Capture, t0);

        // ---- calibrate ----------------------------------------------------
        // Identical in both modes: calibration operates on the captured
        // pools (DartQuant's locality), never on the weights.
        stage(Stage::Calibrate);
        let t0 = Instant::now();
        let outcome = rotation.calibrate(&ctx, pools.as_ref())?;
        stats.calibrate_time = stage_done(Stage::Calibrate, t0);
        stats.loss_curves = outcome.loss_curves;
        let rotation_set = outcome.rotation;

        // ---- fuse + smooth ------------------------------------------------
        stage(Stage::Fuse);
        let t0 = Instant::now();
        let mut working: Option<Weights> = None; // in-memory mode only
        match &store {
            Some(s) => {
                if let Some(rot) = &rotation_set {
                    rotation::fuse_streamed(s, rot)?;
                }
                if smooth && !model_cfg.is_moe() {
                    let stats_seqs =
                        corpus.calib_sequences(4.min(cfg.calib_sequences), cfg.calib_seq_len);
                    let sstats = SmoothStats::capture_streamed(s, &stats_seqs)?;
                    rotation::smooth_streamed(s, &sstats, 0.5)?;
                }
            }
            None => {
                let mut w = match &rotation_set {
                    Some(rot) => rotation::fuse(weights, rot),
                    None => weights.clone(),
                };
                if smooth && !model_cfg.is_moe() {
                    let stats_seqs =
                        corpus.calib_sequences(4.min(cfg.calib_sequences), cfg.calib_seq_len);
                    let sstats = SmoothStats::capture(&w, &stats_seqs);
                    w = rotation::smooth_scales(&w, &sstats, 0.5);
                }
                working = Some(w);
            }
        }
        stats.fuse_time = stage_done(Stage::Fuse, t0);

        // ---- weight quantization -----------------------------------------
        stage(Stage::Quantize);
        let t0 = Instant::now();
        let quantizer_label = if cfg.bits.w >= 16 {
            "none".to_string()
        } else {
            quantizer.name().to_string()
        };
        let quantized = match (&store, working) {
            (Some(s), _) => {
                if cfg.bits.w < 16 {
                    quantizer.quantize_streamed(&ctx, s)?;
                }
                stats.peak_weight_bytes = s.peak_resident_bytes();
                // The in-memory hand-off: every stage ran under the
                // budget; the report's `Weights` is the caller's explicit
                // decision to hold the full result.
                s.materialize()?
            }
            (None, Some(w)) => {
                if cfg.bits.w >= 16 {
                    w
                } else {
                    quantizer.quantize(&ctx, &w)?
                }
            }
            (None, None) => unreachable!("in-memory runs always build a working model"),
        };
        stats.quantize_time = stage_done(Stage::Quantize, t0);

        stats.total_time = t_total.elapsed();
        stats.peak_job_bytes = gate.peak_bytes();
        let model_bytes = quantized.nbytes();
        let (linear_dense_bytes, linear_actual_bytes) = quantized.linear_bytes();
        Ok(PipelineReport {
            weights: quantized,
            rotation: rotation_set,
            stats,
            method: method_label,
            quantizer: quantizer_label,
            dialect: cfg.calib_dialect,
            model_bytes,
            linear_dense_bytes,
            linear_actual_bytes,
        })
    }
}

/// Unique scratch location for a streamed run's spill artifact:
/// `stream_dir` (or the OS temp dir) / a name keyed by model, pid and a
/// process-wide counter, so concurrent runs never collide.
fn spill_path(cfg: &PipelineConfig, model: &str) -> PathBuf {
    use std::sync::atomic::{AtomicUsize, Ordering};
    static SPILL_SEQ: AtomicUsize = AtomicUsize::new(0);
    let dir = cfg.stream_dir.clone().unwrap_or_else(std::env::temp_dir);
    let seq = SPILL_SEQ.fetch_add(1, Ordering::Relaxed);
    dir.join(format!("dartquant-stream-{model}-{}-{seq}.dartq", std::process::id()))
}

/// Removes a streamed run's spill artifact when the run ends — on
/// success *and* on every error path (the store is a scratch file, not a
/// checkpoint; persist results with `Weights::save`).
struct SpillGuard(PathBuf);

impl Drop for SpillGuard {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.0);
    }
}
