//! # DartQuant — rotational distribution calibration for LLM quantization
//!
//! A reproduction of *DartQuant: Efficient Rotational Distribution
//! Calibration for LLM Quantization* (NeurIPS 2025) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the calibration coordinator, quantization
//!   pipeline, baselines, evaluation harness and CLI. Python is never on
//!   this path.
//! * **L2/L1 (`python/compile/`)** — JAX calibration graphs and Pallas
//!   kernels, AOT-lowered once to `artifacts/*.hlo.txt` by `make artifacts`
//!   and executed here through the PJRT C API (`runtime`).
//!
//! ## The pipeline API
//!
//! Quantization methods are *compositions*: a [`coordinator::RotationStrategy`]
//! (how R1/R2 are produced — none, random Hadamard, end-to-end Cayley,
//! DartQuant's whip + QR-Orth calibration) × a
//! [`coordinator::WeightQuantizer`] (RTN, GPTQ, OmniQuant, QUIK/Atom
//! mixed precision) × optional SmoothQuant scaling. The
//! [`coordinator::MethodRegistry`] maps names ("dartquant", "quarot", …)
//! to composed [`coordinator::MethodSpec`]s; out-of-tree strategies
//! register a spec and run through the same pipeline without touching the
//! coordinator.
//!
//! Runs go through the staged builder:
//!
//! ```no_run
//! use dartquant::coordinator::Pipeline;
//! use dartquant::model::{BitSetting, ModelConfig, Weights};
//! # fn main() -> anyhow::Result<()> {
//! let cfg = ModelConfig::builtin("llama2-tiny")?;
//! let weights = Weights::default_synthetic(&cfg, 1);
//! let rt = dartquant::runtime::Runtime::open(
//!     dartquant::runtime::Runtime::default_dir())?;
//! let report = Pipeline::builder(&weights)
//!     .method("dartquant")?
//!     .bits(BitSetting::W4A4)
//!     .budget(Some(24 << 20)) // scaled single-3090 admission gate
//!     .workers(8)             // per-layer calibration jobs in parallel
//!     .run(&rt)?;             // or .run_native() without artifacts
//! println!("{}", report.to_json());
//! # Ok(()) }
//! ```
//!
//! The four stages (capture → calibrate → fuse/smooth → quantize) are
//! individually timed and bracketed by typed
//! [`coordinator::PipelineEvent`]s on an observer hook — the single
//! progress/reporting surface the CLI, examples and benches consume.
//! [`coordinator::PipelineReport`] serializes to JSON via [`util::json`].
//!
//! The calibrate stage decomposes into independent per-layer jobs run by
//! the parallel [`coordinator::Scheduler`]; per-job seeding and ordered
//! event delivery make parallel runs bit-identical to serial ones (the
//! determinism contract — `docs/CONCURRENCY.md`).
//!
//! `.streaming(true).resident_budget(Some(bytes))` switches to
//! **out-of-core** execution: weights spill to an indexed on-disk
//! artifact and every stage works through [`model::WeightStore`]
//! checkout/checkin leases, bounding peak resident weight bytes by the
//! budget instead of model size — with a byte-identical canonical
//! report (`docs/STREAMING.md`).
//!
//! The legacy `Method` enum and `run_pipeline` survive as thin shims over
//! the registry and builder.
//!
//! See `docs/ARCHITECTURE.md` for the end-to-end module map and data
//! flow, and `README.md` for the quickstart and verify entry points.
//! The determinism / panic-safety contracts are mechanically enforced
//! by the in-tree [`lint`] pass (`dqlint`, `make lint` —
//! `docs/LINTS.md`).

pub mod linalg;
pub mod calib;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod lint;
pub mod model;
pub mod quant;
pub mod rotation;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod util;
