//! # DartQuant — rotational distribution calibration for LLM quantization
//!
//! A reproduction of *DartQuant: Efficient Rotational Distribution
//! Calibration for LLM Quantization* (NeurIPS 2025) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the calibration coordinator, quantization
//!   pipeline, baselines, evaluation harness and CLI. Python is never on
//!   this path.
//! * **L2/L1 (`python/compile/`)** — JAX calibration graphs and Pallas
//!   kernels, AOT-lowered once to `artifacts/*.hlo.txt` by `make artifacts`
//!   and executed here through the PJRT C API (`runtime`).
//!
//! See `DESIGN.md` for the system inventory and the per-experiment index.

pub mod linalg;
pub mod calib;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod model;
pub mod quant;
pub mod rotation;
pub mod runtime;
pub mod tensor;
pub mod util;
